"""Hierarchical buffer memory (docs/memory.md): sub-buffers, zero-copy
map/unmap bookkeeping, and size-class pooling over the bufalloc arena.

Three layers on top of :mod:`repro.runtime.bufalloc` /
:mod:`repro.runtime.platform`:

* :class:`SubBuffer` — ``clCreateSubBuffer`` (OpenCL §5.2): an aliased
  view carved from a parent :class:`~repro.runtime.platform.Buffer` at a
  byte ``origin``, subject to the device's ``mem_base_addr_align`` rule.
  The view owns no memory: reads and writes go straight through to the
  parent's storage, and a write through *any* view invalidates exactly
  the overlapping span of the parent's other device copies (span-granular
  residency, :meth:`~repro.runtime.bufalloc.ResidencyTracker.wrote_span`).
* :class:`MappedRegion` — the object ``CommandQueue.enqueue_map_buffer``
  (OpenCL §5.4.2) publishes: a zero-copy ndarray view into the buffer
  payload, valid between the map event's completion and the unmap
  command.  ``MAP_WRITE_INVALIDATE`` maps skip the read-back sync hook —
  the contents are undefined until the host writes them.
* :class:`BufferPool` — a size-class free-list pool over a
  :class:`~repro.runtime.bufalloc.Bufalloc` arena.  Serving-style
  workloads allocate and free same-sized KV blocks per request; the pool
  turns that steady state into O(1) free-list pops instead of first-fit
  walks over the chunk list (benchmarks/bench_memory.py measures the
  throughput gap).

The command-queue integration (map/unmap as DAG commands, write-mapped
launch guard) lives in :mod:`repro.runtime.queue`; event-ordered
migration over these primitives lives in :mod:`repro.runtime.scheduler`.
"""

from __future__ import annotations

import threading
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.errors import MapError
from .bufalloc import Bufalloc, Chunk, OutOfMemory
from .platform import Buffer


# map flags (clEnqueueMapBuffer map_flags analogues)
MAP_READ = "r"                    # CL_MAP_READ
MAP_WRITE = "w"                   # CL_MAP_WRITE
MAP_READ_WRITE = "rw"
MAP_WRITE_INVALIDATE = "wi"       # CL_MAP_WRITE_INVALIDATE_REGION

_VALID_FLAGS = (MAP_READ, MAP_WRITE, MAP_READ_WRITE, MAP_WRITE_INVALIDATE)


def _flat_view(arr: np.ndarray) -> np.ndarray:
    """A writable 1-D view of ``arr`` (never a copy)."""
    flat = arr.reshape(-1)
    if not np.shares_memory(flat, arr):  # pragma: no cover - guards misuse
        raise MapError("buffer payload is not contiguous; cannot alias")
    return flat


# ---------------------------------------------------------------------------
# Sub-buffers (clCreateSubBuffer, OpenCL §5.2)
# ---------------------------------------------------------------------------

class SubBuffer:
    """An aliased view of ``[origin, origin + nbytes)`` of a parent buffer.

    Duck-compatible with :class:`~repro.runtime.platform.Buffer` where the
    runtime needs it (``data`` get/set, ``mark_written*``, ``root``,
    ``release``) so kernel launches, read/write enqueues, and maps accept
    either.  ``data`` is computed from the parent's *current* payload on
    every access, so replacing the parent array (a whole-buffer write)
    never leaves a view dangling.
    """

    def __init__(self, parent: Buffer, origin: int, nbytes: int):
        if isinstance(parent, SubBuffer):
            # OpenCL: buffer must not itself be a sub-buffer object
            raise MapError("cannot carve a sub-buffer from a sub-buffer")
        align = parent.device.info.mem_base_addr_align
        if origin % align != 0:
            raise MapError(
                f"sub-buffer origin {origin} violates the device "
                f"mem_base_addr_align of {align} bytes "
                f"(CL_MISALIGNED_SUB_BUFFER_OFFSET)")
        if nbytes <= 0 or origin < 0 or origin + nbytes > parent.nbytes:
            raise MapError(
                f"sub-buffer [{origin}, {origin + nbytes}) outside parent "
                f"of {parent.nbytes} bytes (CL_INVALID_BUFFER_SIZE)")
        if origin % parent.itemsize or nbytes % parent.itemsize:
            raise MapError(
                f"sub-buffer [{origin}, {origin + nbytes}) not a whole "
                f"number of {parent.dtype} elements")
        self.parent = parent
        self.device = parent.device
        self.dtype = parent.dtype
        self.itemsize = parent.itemsize
        self.origin = origin
        self.nbytes = nbytes
        self.n_elems = nbytes // parent.itemsize

    @property
    def root(self) -> Buffer:
        return self.parent

    @property
    def data(self) -> np.ndarray:
        """Zero-copy view into the parent's payload (recomputed per
        access, so it always aliases the parent's current array)."""
        lo = self.origin // self.itemsize
        return _flat_view(self.parent.data)[lo:lo + self.n_elems]

    @data.setter
    def data(self, value) -> None:
        """Write through the view: in-place into the parent storage."""
        # a prior launch may have installed an immutable (device-owned)
        # array as the parent payload; copy-on-write before aliasing it
        if not self.parent.data.flags.writeable:
            self.parent.data = np.array(self.parent.data)
        lo = self.origin // self.itemsize
        _flat_view(self.parent.data)[lo:lo + self.n_elems] = \
            np.asarray(value, dtype=self.dtype).reshape(-1)

    # -- residency: writes through a view invalidate parent-relative spans --
    def mark_written_span(self, lo: int, hi: int) -> None:
        self.parent.mark_written_span(self.origin + lo, self.origin + hi)

    def mark_written(self) -> None:
        self.mark_written_span(0, self.nbytes)

    @property
    def map_count(self) -> int:
        return self.parent.map_count

    def release(self) -> None:
        """Views own no memory; releasing is a no-op (the parent's chunk
        stays allocated until the parent is released)."""

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"<SubBuffer [{self.origin}, {self.origin + self.nbytes}) "
                f"of {self.parent.nbytes}B {self.dtype}>")


def create_sub_buffer(parent: Buffer, origin: int, nbytes: int) -> SubBuffer:
    """clCreateSubBuffer with CL_BUFFER_CREATE_TYPE_REGION: an aliased
    ``[origin, origin + nbytes)`` byte view of ``parent``."""
    return SubBuffer(parent, origin, nbytes)


# ---------------------------------------------------------------------------
# Mapped regions (clEnqueueMapBuffer / clEnqueueUnmapMemObject, §5.4.2)
# ---------------------------------------------------------------------------

class MappedRegion:
    """One active host mapping of a buffer span.

    Created by ``CommandQueue.enqueue_map_buffer``; :attr:`array` is
    ``None`` until the map command completes (wait on :attr:`event`),
    then a **zero-copy ndarray view** into the buffer payload — host
    reads and writes touch device memory directly, the pocl CPU-driver
    case where map returns a pointer into the buffer instead of a bounce
    copy.  After the unmap command runs, :attr:`array` is ``None`` again
    and writes (for write-flagged maps) have been published to the
    residency tracker as a span-granular invalidation.
    """

    def __init__(self, buf, offset: int, nbytes: int, flags: str):
        if flags not in _VALID_FLAGS:
            raise MapError(f"bad map flags {flags!r}; one of {_VALID_FLAGS}")
        if nbytes <= 0 or offset < 0 or offset + nbytes > buf.nbytes:
            raise MapError(
                f"map [{offset}, {offset + nbytes}) outside buffer of "
                f"{buf.nbytes} bytes (CL_INVALID_VALUE)")
        if offset % buf.itemsize or nbytes % buf.itemsize:
            raise MapError(
                f"map [{offset}, {offset + nbytes}) not a whole number "
                f"of {buf.dtype} elements")
        self.buf = buf
        self.offset = offset                 # bytes, buffer-relative
        self.nbytes = nbytes
        self.flags = flags
        # absolute span within the root allocation (views compose)
        self.abs_span: Tuple[int, int] = (buf.origin + offset,
                                          buf.origin + offset + nbytes)
        self.event = None                    # set by enqueue_map_buffer
        self.unmap_event = None              # set by enqueue_unmap_buffer
        self.array: Optional[np.ndarray] = None
        self._active = False

    @property
    def writable(self) -> bool:
        return self.flags in (MAP_WRITE, MAP_READ_WRITE,
                              MAP_WRITE_INVALIDATE)

    @property
    def active(self) -> bool:
        return self._active

    def get(self, timeout: Optional[float] = None) -> np.ndarray:
        """Wait for the map command and return the published view.

        Flushes the owning queue first — the ``blocking_map`` semantics
        of clEnqueueMapBuffer (a blocking map implies a flush, otherwise
        the wait could never resolve)."""
        if self.event.queue is not None:
            self.event.queue.flush()
        self.event.wait(timeout)
        return self.array

    def overlaps(self, lo: int, hi: int) -> bool:
        """Does this region's root-absolute span intersect ``[lo, hi)``?"""
        a, b = self.abs_span
        return a < hi and lo < b

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        state = "active" if self._active else \
            ("unmapped" if self.unmap_event is not None else "pending")
        return (f"<MappedRegion {self.flags} "
                f"[{self.abs_span[0]}, {self.abs_span[1]}) {state}>")


# ---------------------------------------------------------------------------
# Size-class buffer pool (serving KV allocations over the arena)
# ---------------------------------------------------------------------------

class BufferPool:
    """Size-class free-list pool over a :class:`Bufalloc` arena.

    ``alloc`` rounds the request up to a power-of-two size class (at
    least ``min_class`` bytes) and serves it from the class free list
    when possible — an O(1) pop with no chunk-list walk, no split, and
    no later coalesce.  Misses fall through to ``arena.alloc``; frees
    return chunks to the class list (bounded by ``max_free_per_class``,
    overflow goes back to the arena).  ``trim`` releases every pooled
    chunk to the arena, and an alloc that hits :class:`OutOfMemory`
    trims and retries once before giving up.

    Rounding to classes trades internal fragmentation (< 2x) for reuse:
    serving's per-request KV blocks are identically sized in steady
    state, so after warm-up every alloc is a hit
    (``benchmarks/bench_memory.py`` gates the throughput ratio).
    """

    def __init__(self, arena: Bufalloc, min_class: int = 256,
                 max_free_per_class: int = 64):
        assert min_class > 0 and max_free_per_class >= 0
        self.arena = arena
        self.min_class = min_class
        self.max_free_per_class = max_free_per_class
        self._free: Dict[int, List[Chunk]] = {}
        # id(chunk) -> (chunk, size class); holding the chunk reference
        # pins the id, so a caller-dropped chunk can never alias a fresh
        # allocation's id and corrupt a free list
        self._class: Dict[int, Tuple[Chunk, int]] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.frees = 0
        self.trims = 0

    def class_of(self, size: int) -> int:
        """The pool size class serving a ``size``-byte request."""
        size = max(int(size), 1)
        return max(self.min_class, 1 << (size - 1).bit_length())

    def alloc(self, size: int) -> Chunk:
        """A chunk of at least ``size`` bytes (exactly one size class)."""
        cls = self.class_of(size)
        with self._lock:
            lst = self._free.get(cls)
            if lst:
                self.hits += 1
                return lst.pop()
            self.misses += 1
            try:
                chunk = self.arena.alloc(cls)
            except OutOfMemory:
                self._trim_locked()
                chunk = self.arena.alloc(cls)   # may re-raise: truly full
            self._class[id(chunk)] = (chunk, cls)
            return chunk

    def free(self, chunk: Chunk) -> None:
        """Return a pool chunk to its class free list."""
        with self._lock:
            entry = self._class.get(id(chunk))
            if entry is None or entry[0] is not chunk:
                raise ValueError("chunk was not allocated by this pool")
            cls = entry[1]
            lst = self._free.setdefault(cls, [])
            if any(c is chunk for c in lst):
                # parking it twice would hand the chunk to two owners
                raise ValueError("double free of pool chunk")
            self.frees += 1
            if len(lst) < self.max_free_per_class:
                lst.append(chunk)
            else:
                del self._class[id(chunk)]
                self.arena.free(chunk)

    def trim(self) -> int:
        """Release every pooled free chunk back to the arena; returns the
        number of bytes returned."""
        with self._lock:
            return self._trim_locked()

    def _trim_locked(self) -> int:
        freed = 0
        for lst in self._free.values():
            for chunk in lst:
                del self._class[id(chunk)]
                freed += chunk.size     # read before free() coalesces it
                self.arena.free(chunk)
            lst.clear()
        if freed:
            self.trims += 1
        return freed

    def pooled_bytes(self) -> int:
        """Bytes currently parked on the free lists (arena-allocated but
        reusable without a first-fit walk)."""
        with self._lock:
            return sum(c.size for lst in self._free.values() for c in lst)

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "frees": self.frees, "trims": self.trims,
                    "pooled_bytes": sum(c.size for lst in self._free.values()
                                        for c in lst),
                    "live_classes": sum(1 for lst in self._free.values()
                                        if lst)}


__all__ = [
    "MapError", "MAP_READ", "MAP_WRITE", "MAP_READ_WRITE",
    "MAP_WRITE_INVALIDATE", "SubBuffer", "create_sub_buffer",
    "MappedRegion", "BufferPool",
]
