"""Multi-device co-execution of one NDRange (docs/runtime.md §Scheduler).

pocl schedules a kernel launch onto *one* device; co-execution engines
(EngineCL, Nozal et al. — PAPERS.md) show that splitting a single NDRange
across heterogeneous devices is where platform portability becomes
throughput.  This module fans one launch out over several
:class:`~repro.runtime.platform.Device`s:

* the NDRange is split along the **linearized work-group axis** into
  contiguous ``group_range`` chunks (work-groups are the only unit OpenCL
  lets you split on: no cross-group synchronization exists);
* **static** mode pre-assigns one contiguous span per device, sized by
  ``weights`` (compute-power ratios, default equal);
* **steal** mode enqueues many small chunks into a shared deque and lets
  each device's drain command pull the next chunk whenever it finishes
  one — self-scheduling, so a slow device simply takes fewer chunks;
* **adaptive** mode (EngineCL's HGuided) is the N-device asymmetric
  scheduler: a per-device :class:`ThroughputModel` (EWMA of groups/sec
  read off the event profiling counters) drives an
  :class:`AdaptiveSplitter` that hands out geometrically shrinking
  chunks proportional to modeled speed, re-weights across launches, and
  — when the frontier drains — *steals* a straggler's in-flight span so
  a stalled device never strands work (chunks are pure, so duplicate
  execution is bitwise-harmless).  Converged weights persist per device
  class through the :class:`~repro.core.autotune.TuningTable`
  (``<ir-hash>|coexec=<class-vector>`` keys), so a warm second run
  starts near the converged split;
* every chunk launch goes through the device's own
  :class:`~repro.runtime.queue.CommandQueue`, so chunk commands carry
  events with full profiling, and the final merge command *waits on all
  chunk events across queues* — a cross-queue event DAG;
* buffer movement is tracked by a
  :class:`~repro.runtime.bufalloc.ResidencyTracker`: a
  :class:`SharedBuffer` is copied to a device on first use and then stays
  resident until some launch writes it, so N chunk launches on one device
  trigger exactly one migration;
* migration is **event-ordered** (docs/memory.md): each pending copy is
  enqueued as an explicit ``transfer`` command on the destination
  device's queue, and chunk commands carry dependency edges on their
  device's transfer events — so a migration to device B overlaps with
  compute already running on device A instead of blocking the enqueue
  path, and transfer cost shows up in the event profile;
* write-invalidation is **span-granular**: the merge records which byte
  spans each device's ``group_range`` chunks actually wrote
  (:meth:`~repro.runtime.bufalloc.ResidencyTracker.wrote_span`), so a
  device's copy goes stale only over the spans *other* devices wrote —
  the next launch re-migrates those spans, not the whole buffer.

Results are **bitwise identical** to a single-device launch of the same
target: a ``group_range`` sub-launch executes exactly the same group ids
with the same group-id decoding, and merging takes each element from the
chunk that wrote it.  (Merging assumes the OpenCL data-race rule already
required for independent commands: distinct work-groups write disjoint
elements.)
"""

from __future__ import annotations

import itertools
import math
import threading
import time
import warnings
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.autotune import TuningTable, default_table
from ..core.errors import InvalidArgError
from ..core.program import Kernel
from .bufalloc import ResidencyTracker, Span
from .events import UserEvent, chunk_counters
from .platform import Buffer, Device, create_buffer
from .queue import CommandQueue, Event

_buf_ids = itertools.count()


def _changed_mask(sub: np.ndarray, ref: np.ndarray) -> np.ndarray:
    """Elements of ``sub`` that differ from ``ref``, treating NaN->NaN
    as *unchanged*: with plain ``!=`` every NaN element of the canonical
    buffer would read as "written by every chunk" (NaN != NaN), letting
    a non-writing chunk's stale NaNs clobber another device's real
    writes in the merge."""
    mask = sub != ref
    if np.issubdtype(sub.dtype, np.floating) or \
            np.issubdtype(sub.dtype, np.complexfloating):
        mask &= ~(np.isnan(sub) & np.isnan(ref))
    return mask


def _mask_to_byte_spans(mask: np.ndarray, itemsize: int,
                        max_runs: int = 64) -> Optional[List[Span]]:
    """Contiguous runs of a flattened element mask, as *exact* byte
    spans, or ``None`` when the write pattern is so scattered that span
    bookkeeping would cost more than it saves.

    ``None`` (not a covering envelope) on overflow is deliberate:
    ``commit_spans`` credits the writer as *valid* over its spans, and
    an over-approximation in that direction could wipe another device's
    overlapping invalidation — the caller must fall back to a
    whole-buffer commit instead."""
    idx = np.flatnonzero(mask.reshape(-1))
    if idx.size == 0:
        return []
    breaks = np.flatnonzero(np.diff(idx) > 1)
    starts = np.concatenate(([idx[0]], idx[breaks + 1]))
    ends = np.concatenate((idx[breaks], [idx[-1]])) + 1
    if len(starts) > max_runs:
        return None
    return [(int(s) * itemsize, int(e) * itemsize)
            for s, e in zip(starts, ends)]


class SharedBuffer:
    """A buffer logically shared by several devices (cl_mem used from
    multiple queues).

    The canonical copy lives on the host (``self.host``); each device
    gets a lazily-allocated :class:`~repro.runtime.platform.Buffer` from
    its own Bufalloc arena, filled on first use and kept valid across
    launches by the residency tracker.  Migration is span-granular:
    :meth:`migrate_to` copies only the byte spans the tracker reports
    stale, so a device whose copy is stale only where *another* device
    wrote re-migrates that span instead of the whole buffer.  ``commit``
    installs a new canonical value (after a merge) and invalidates every
    device copy; :meth:`commit_spans` is the granular variant that
    credits each device with the spans it wrote itself.
    """

    def __init__(self, host: np.ndarray, name: str,
                 tracker: ResidencyTracker):
        self.host = np.asarray(host)
        self.name = name
        # residency is keyed by a per-instance nonce, not the user-chosen
        # name: two SharedBuffers reusing a name on one tracker must not
        # alias each other's residency state (stale device data)
        self._key = f"{name}#{next(_buf_ids)}"
        self.tracker = tracker
        self._device_bufs: Dict[Device, Buffer] = {}
        self._lock = threading.Lock()

    @property
    def nbytes(self) -> int:
        return int(self.host.nbytes)

    @property
    def key(self) -> str:
        """The residency-tracker key of this buffer instance."""
        return self._key

    def migrate_to(self, device: Device) -> int:
        """Make the device copy current; returns bytes actually copied.

        Copies exactly the spans the tracker reports stale — the body of
        an event-ordered ``transfer`` command, but also safe to call
        inline (it is idempotent between writes).  Safe under
        concurrency: the copy happens at most once per (buffer, device)
        between writes."""
        with self._lock:
            buf = self._device_bufs.get(device)
            if buf is None:
                buf = create_buffer(device, self.host.size,
                                    str(self.host.dtype))
                self._device_bufs[device] = buf
            spans = self.tracker.acquire_spans(self._key, device,
                                               self.nbytes)
            if not spans:
                return 0
            if spans == [(0, self.nbytes)]:
                buf.data = self.host.copy()
                return self.nbytes
            itemsize = self.host.dtype.itemsize
            src = self.host.reshape(-1)
            dst = buf.data.reshape(-1)
            moved = 0
            for lo, hi in spans:
                dst[lo // itemsize:hi // itemsize] = \
                    src[lo // itemsize:hi // itemsize]
                moved += hi - lo
            return moved

    def device_array(self, device: Device) -> np.ndarray:
        """The device-resident copy, migrating host -> device if stale."""
        self.migrate_to(device)
        with self._lock:
            return self._device_bufs[device].data

    def clean_on(self, device: Device) -> bool:
        """True when the device copy exists and has no stale spans (a
        transfer command for it would be a no-op)."""
        with self._lock:
            if device not in self._device_bufs:
                return False
        return self.tracker.resident(self._key, device)

    def store_local(self, device: Device, arr: np.ndarray) -> None:
        """Install a chunk launch's result as the device-local payload
        (the device's own writes land in its copy, so only spans written
        by *other* devices ever need re-migration)."""
        a = np.asarray(arr)
        if not a.flags.writeable:       # e.g. a jax Array export
            a = a.copy()
        with self._lock:
            buf = self._device_bufs.get(device)
            if buf is not None:
                buf.data = a

    def commit(self, merged: np.ndarray) -> None:
        """Install a merged result as the canonical host copy; all device
        copies become stale (the next read on any device re-migrates)."""
        with self._lock:
            self.host = np.asarray(merged)
            self.tracker.wrote(self._key, "host")

    def commit_spans(self, merged: np.ndarray,
                     written: Dict[Device, List[Span]]) -> None:
        """Granular commit: install the merged canonical copy, crediting
        each device with the byte spans its own chunks wrote.

        Every device copy goes stale exactly over the spans *other*
        devices wrote (`wrote_span` pairwise), and the host — which holds
        the full merge — is validated everywhere.  This is the
        write-invalidation granularity fix for ``group_range``
        sub-launches: a whole-buffer invalidate here would force every
        device to re-copy the full buffer on the next launch."""
        with self._lock:
            self.host = np.asarray(merged)
            for device, spans in written.items():
                for lo, hi in spans:
                    self.tracker.wrote_span(self._key, device, lo, hi)
            self.tracker.validate(self._key, "host")

    def release(self) -> None:
        """Free every device-side chunk and forget residency."""
        with self._lock:
            for buf in self._device_bufs.values():
                buf.release()
            self._device_bufs.clear()
            self.tracker.drop(self._key)


def split_groups(n_groups: int, shares: Sequence[float]
                 ) -> List[Tuple[int, int]]:
    """Split ``[0, n_groups)`` into contiguous spans proportional to
    ``shares`` (one span per share).

    Shares need not sum to 1 — only the ratios matter.  A zero share is
    legal and yields an empty span (the caller decides whether that
    device participates); so is ``n_groups < len(shares)``, where
    rounding leaves some spans empty.  Degenerate inputs — an empty
    share list, a negative/NaN/infinite share, a non-numeric share, or a
    non-positive total — raise a typed
    :class:`~repro.core.errors.InvalidArgError` (CL_INVALID_VALUE)
    instead of producing overlapping or nonsensical spans."""
    try:
        n = int(n_groups)
    except (TypeError, ValueError):
        raise InvalidArgError(
            f"n_groups must be an integer, got {n_groups!r}") from None
    if n < 0:
        raise InvalidArgError(f"n_groups must be >= 0, got {n}")
    try:
        vals = [float(s) for s in shares]
    except (TypeError, ValueError):
        raise InvalidArgError(
            f"split shares must be numeric, got {shares!r}") from None
    if not vals:
        raise InvalidArgError("split_groups needs at least one share")
    for s in vals:
        if not math.isfinite(s) or s < 0:
            raise InvalidArgError(
                f"split shares must be finite and >= 0, got {vals}")
    total = sum(vals)
    if total <= 0:
        raise InvalidArgError(f"split shares must sum > 0, got {vals}")
    bounds = [0]
    acc = 0.0
    for s in vals[:-1]:
        acc += s
        bounds.append(min(n, round(n * acc / total)))
    bounds.append(n)
    # enforce monotonicity after rounding
    for i in range(1, len(bounds)):
        bounds[i] = max(bounds[i], bounds[i - 1])
    return [(bounds[i], bounds[i + 1]) for i in range(len(vals))]


def device_class(device) -> str:
    """The persistence class of a device: devices of one class share one
    tuning-table weight entry.  Wrappers (e.g.
    :class:`~repro.runtime.platform.ThrottledDevice`) override
    ``coexec_class``; plain devices fall back to their driver kind, so
    e.g. all ``vector`` devices of a platform learn one weight."""
    cls = getattr(device, "coexec_class", None)
    if cls:
        return str(cls)
    info = getattr(device, "info", None)
    return str(getattr(info, "driver", device))


class ThroughputModel:
    """Per-device online throughput model: an EWMA of observed execution
    rate in work-groups per second, fed by the profiling counters
    stamped on every chunk :class:`~repro.runtime.events.Event`.

    ``weights()`` turns modeled rates into a normalized split: devices
    with no observations yet are assumed average (equal split when
    nothing is known), so a cold N-device launch degrades gracefully to
    the symmetric case.  Degenerate observations — zero/negative
    duration, non-finite rate, failed events — are dropped, which is
    what keeps the harness invariant *weights stay normalized and
    finite* true under arbitrary traces.

    A warm start (:meth:`seed`, fed from the tuning table's persisted
    per-class weights) holds only until the first real observation of
    that device, which *replaces* it instead of blending: persisted
    weights are relative shares, not groups/sec, so mixing the two
    scales would distort ratios between already-measured and
    still-seeded devices.
    """

    def __init__(self, alpha: float = 0.5):
        if not (0.0 < float(alpha) <= 1.0):
            raise InvalidArgError(
                f"EWMA alpha must be in (0, 1], got {alpha}")
        self.alpha = float(alpha)
        self._rate: Dict[object, float] = {}
        self._seeded: set = set()
        self._lock = threading.Lock()

    def seed(self, device, rate: float) -> bool:
        """Warm-start a device's modeled rate (any positive scale — only
        ratios matter).  Ignored when invalid or when the device already
        has a measured rate.  Returns True when applied."""
        try:
            r = float(rate)
        except (TypeError, ValueError):
            return False
        if not math.isfinite(r) or r <= 0:
            return False
        with self._lock:
            if device in self._rate and device not in self._seeded:
                return False
            self._rate[device] = r
            self._seeded.add(device)
        return True

    def observe(self, device, groups: int, seconds: float) -> bool:
        """Fold one measured chunk (``groups`` over ``seconds``) into the
        device's EWMA.  Returns False (and changes nothing) for
        degenerate samples."""
        try:
            g, s = float(groups), float(seconds)
        except (TypeError, ValueError):
            return False
        if not (math.isfinite(g) and math.isfinite(s)) or g <= 0 or s <= 0:
            return False
        rate = g / s
        if not math.isfinite(rate) or rate <= 0:
            return False
        with self._lock:
            prev = self._rate.get(device)
            if prev is None or device in self._seeded:
                # first real measurement: replace (see class docstring)
                self._rate[device] = rate
                self._seeded.discard(device)
            else:
                self._rate[device] = \
                    self.alpha * rate + (1 - self.alpha) * prev
        return True

    def observe_event(self, device, groups: int, event: Event) -> bool:
        """Feed one completed chunk event through the profiling-counter
        extraction layer (:func:`~repro.runtime.events.chunk_counters`)."""
        rows = chunk_counters([event])
        if not rows or not rows[0]["ok"]:
            return False
        return self.observe(device, groups, rows[0]["duration_s"])

    def rate(self, device) -> Optional[float]:
        """Modeled groups/sec for ``device`` (None when never observed
        or seeded)."""
        with self._lock:
            return self._rate.get(device)

    def weights(self, devices: Sequence[object]) -> List[float]:
        """Normalized relative speeds over ``devices``: finite, positive,
        summing to 1.  Unobserved devices get the mean known rate."""
        with self._lock:
            known = [self._rate[d] for d in devices if d in self._rate]
            fill = (sum(known) / len(known)) if known else 1.0
            raw = [self._rate.get(d, fill) for d in devices]
        total = sum(raw)
        return [r / total for r in raw]


class AdaptiveSplitter:
    """HGuided self-scheduling chunker over a shared group frontier
    (EngineCL, Nozal et al. — PAPERS.md).

    Each call to :meth:`next_chunk` hands the asking device the next
    contiguous span off the frontier, sized
    ``max(min_chunk, remaining * weight / divisor)`` — large chunks
    early (low scheduling overhead), geometrically shrinking toward the
    tail (load balance), proportional to the device's modeled speed
    (asymmetry).  When the frontier is empty but spans are still in
    flight, a finished device **steals** a straggler's span and
    re-executes it: chunks are pure and deterministic, so the duplicate
    writes identical bytes and the merge stays bitwise-correct, while
    the launch no longer waits for the straggler.

    Thread-safe: the co-executor calls it from event-completion
    callbacks on device worker threads.  :meth:`complete` returns True
    exactly once — when the completed spans first cover the whole range
    — which is the co-executor's signal to fire the merge gate.
    """

    def __init__(self, n_groups: int, devices: Sequence[object],
                 model: ThroughputModel, min_chunk: int = 1,
                 divisor: float = 2.0):
        if int(n_groups) < 0:
            raise InvalidArgError(f"n_groups must be >= 0, got {n_groups}")
        if not devices:
            raise InvalidArgError("AdaptiveSplitter needs >= 1 device")
        if int(min_chunk) < 1:
            raise InvalidArgError(f"min_chunk must be >= 1, got {min_chunk}")
        if not math.isfinite(float(divisor)) or float(divisor) < 1.0:
            raise InvalidArgError(f"divisor must be >= 1, got {divisor}")
        self.n_groups = int(n_groups)
        self.devices = list(devices)
        self.model = model
        self.min_chunk = int(min_chunk)
        self.divisor = float(divisor)
        self._next = 0                       # frontier: first unassigned group
        self._lock = threading.Lock()
        # span -> devices currently executing it (dispensed, not completed)
        self._inflight: Dict[Tuple[int, int], List[object]] = {}
        self._done: List[Tuple[int, int]] = []   # merged completed spans
        self._finished = self.n_groups == 0      # empty range: nothing to do
        self.chunks: Dict[object, int] = {d: 0 for d in self.devices}
        self.dispensed: Dict[object, int] = {d: 0 for d in self.devices}
        self.steals: Dict[object, int] = {d: 0 for d in self.devices}

    def next_chunk(self, device) -> Optional[Tuple[int, int]]:
        """The next span for ``device``: a fresh frontier chunk sized by
        modeled speed, else a steal of a straggler's in-flight span, else
        None (nothing useful left for this device)."""
        with self._lock:
            rem = self.n_groups - self._next
            if rem > 0:
                share = self.model.weights(self.devices)[
                    self.devices.index(device)]
                size = max(self.min_chunk,
                           int(math.ceil(rem * share / self.divisor)))
                size = min(size, rem)
                span = (self._next, self._next + size)
                self._next += size
                self._inflight.setdefault(span, []).append(device)
                self.chunks[device] += 1
                self.dispensed[device] += size
                return span
            # frontier drained: steal one straggler span (at most one
            # duplicate per span — a second executor buys nothing)
            for span, owners in self._inflight.items():
                if device not in owners and len(owners) == 1:
                    owners.append(device)
                    self.chunks[device] += 1
                    self.dispensed[device] += span[1] - span[0]
                    self.steals[device] += 1
                    return span
            return None

    def complete(self, device, span: Tuple[int, int]) -> bool:
        """Record that ``device`` finished ``span``.  Returns True exactly
        once: when completed spans first cover ``[0, n_groups)``."""
        with self._lock:
            self._inflight.pop(span, None)
            lo, hi = span
            merged: List[Tuple[int, int]] = []
            for a, b in self._done + [(int(lo), int(hi))]:
                merged.append((a, b))
            merged.sort()
            out: List[Tuple[int, int]] = []
            for a, b in merged:
                if out and a <= out[-1][1]:
                    out[-1] = (out[-1][0], max(out[-1][1], b))
                else:
                    out.append((a, b))
            self._done = out
            covered = sum(b - a for a, b in out)
            if not self._finished and covered >= self.n_groups:
                self._finished = True
                return True
            return False

    @property
    def finished(self) -> bool:
        with self._lock:
            return self._finished

    def pending_spans(self) -> List[Tuple[int, int]]:
        """Spans dispensed but not yet completed (stragglers)."""
        with self._lock:
            return list(self._inflight)


class CoExecStats:
    """What one co-executed launch did: chunks and groups per device,
    events (with profiling), migrations — including the event-ordered
    transfer commands — and wall time."""

    def __init__(self) -> None:
        self.mode = ""
        self.n_groups = 0
        self.chunks_per_device: Dict[str, int] = {}
        self.groups_per_device: Dict[str, int] = {}
        # chunks a device executed beyond its own assignment: re-executed
        # straggler spans in "adaptive" mode, chunks pulled from another
        # device's equal-split territory in "steal" mode (0 in "static")
        self.steals_per_device: Dict[str, int] = {}
        # modeled normalized split after the launch ("adaptive" only)
        self.weights: Dict[str, float] = {}
        self.events: List[Event] = []
        self.transfer_events: List[Event] = []
        self.migrations = 0
        self.partial_migrations = 0
        self.bytes_migrated = 0
        self.residency_hits = 0
        self.wall_s = 0.0

    def migration_overlap_s(self) -> float:
        """Seconds of transfer time that ran concurrently with some
        kernel chunk (event-profile window intersection) — the time
        event-ordered migration hid behind compute.  Kernel windows are
        unioned first so concurrent chunks on several devices cannot
        count one transfer interval twice; the result is bounded by the
        summed transfer durations."""
        kernels = sorted((e.start_ns, e.end_ns) for e in self.events
                         if e.kind == "kernel" and e.start_ns and e.end_ns)
        merged: List[Tuple[int, int]] = []
        for ks, ke in kernels:
            if merged and ks <= merged[-1][1]:
                merged[-1] = (merged[-1][0], max(merged[-1][1], ke))
            else:
                merged.append((ks, ke))
        total = 0
        for t in self.transfer_events:
            if not (t.start_ns and t.end_ns):
                continue
            for ks, ke in merged:
                total += max(0, min(t.end_ns, ke) - max(t.start_ns, ks))
        return total / 1e9

    def as_dict(self) -> Dict[str, object]:
        return {"mode": self.mode, "n_groups": self.n_groups,
                "chunks_per_device": dict(self.chunks_per_device),
                "groups_per_device": dict(self.groups_per_device),
                "steals_per_device": dict(self.steals_per_device),
                "weights": dict(self.weights),
                "migrations": self.migrations,
                "partial_migrations": self.partial_migrations,
                "bytes_migrated": self.bytes_migrated,
                "transfer_commands": len(self.transfer_events),
                "residency_hits": self.residency_hits,
                "wall_s": self.wall_s}


class CoExecutor:
    """Fans ND-range launches out across multiple devices.

    Parameters
    ----------
    devices:
        The participating devices; each gets a private out-of-order
        :class:`CommandQueue`.
    chunks_per_device:
        Granularity of the ``steal`` mode: the NDRange is cut into
        ``chunks_per_device * len(devices)`` chunks for self-scheduling.
    tuning_table:
        Where ``adaptive`` mode persists converged per-device-class
        split weights (and warm-starts from them).  Defaults to the
        process-default :func:`~repro.core.autotune.default_table`;
        pass an explicit table for isolation.
    min_chunk_groups / hguided_divisor / ewma_alpha:
        Adaptive-mode knobs: smallest chunk the splitter dispenses, the
        HGuided shrink divisor (chunk = remaining * weight / divisor),
        and the throughput model's EWMA smoothing factor.
    """

    def __init__(self, devices: Sequence[Device],
                 chunks_per_device: int = 4,
                 tuning_table: Optional[TuningTable] = None,
                 min_chunk_groups: int = 1,
                 hguided_divisor: float = 2.0,
                 ewma_alpha: float = 0.5):
        if not devices:
            raise InvalidArgError("CoExecutor needs at least one device")
        self.devices = list(devices)
        self.chunks_per_device = chunks_per_device
        self.tuning_table = tuning_table
        self.min_chunk_groups = int(min_chunk_groups)
        self.hguided_divisor = float(hguided_divisor)
        # the throughput model outlives launches: that is what
        # "re-weights across launches" means — launch k+1's first split
        # uses launch k's converged rates
        self.throughput = ThroughputModel(alpha=ewma_alpha)
        self.tracker = ResidencyTracker()
        self.queues = {d: CommandQueue(d, out_of_order=True, workers=2)
                       for d in self.devices}
        self._kernels: Dict[tuple, object] = {}
        self.last_stats: Optional[CoExecStats] = None

    def _table(self) -> TuningTable:
        return self.tuning_table if self.tuning_table is not None \
            else default_table()

    # -- buffers ---------------------------------------------------------------
    def shared_buffer(self, host: np.ndarray, name: str) -> SharedBuffer:
        """Wrap a host array for residency-tracked multi-device use.
        Reusing the SharedBuffer across ``run`` calls is what makes
        repeat launches migration-free."""
        return SharedBuffer(host, name, self.tracker)

    # -- kernel compilation (per device: enqueue-time specialization) ----------
    def _kernel_for(self, device: Device, build: Callable,
                    local_size: Sequence[int]):
        key = (device, build, tuple(local_size))
        k = self._kernels.get(key)
        if k is None:
            k = device.compile(build, local_size)
            self._kernels[key] = k
        return k

    # -- the co-executed launch -------------------------------------------------
    def launch(self, kernel: Kernel, global_size: Sequence[int],
               local_size: Sequence[int],
               mode: str = "static",
               weights: Optional[Sequence[float]] = None
               ) -> Dict[str, np.ndarray]:
        """Co-execute a first-class :class:`~repro.core.program.Kernel`
        over ``global_size``, split across this executor's devices
        (docs/host_api.md).

        Buffer arguments bound on the kernel must be host ndarrays
        (wrapped in throwaway :class:`SharedBuffer`\\ s for the launch)
        or :class:`SharedBuffer`\\ s (keep residency across calls); a
        device-bound :class:`~repro.runtime.platform.Buffer` is rejected
        with a typed error — it belongs on a single-device queue.  Each
        device specializes the kernel through its own compilation cache
        and the program's shared plan tier, so N devices run region
        formation once.  Results are bitwise-identical to a
        single-device launch of the same kernel object."""
        buffers, scalars = kernel.launch_args(accept=("host", "shared"))
        kernels = {d: kernel.bind(d, local_size) for d in self.devices}
        return self._co_run(kernels, local_size, global_size, buffers,
                            scalars, mode, weights,
                            persist_key=kernel.ir_hash)

    def run(self, build: Callable, local_size: Sequence[int],
            global_size: Sequence[int],
            buffers: Dict[str, Union[np.ndarray, SharedBuffer]],
            scalars: Optional[Dict[str, object]] = None,
            mode: str = "static",
            weights: Optional[Sequence[float]] = None
            ) -> Dict[str, np.ndarray]:
        """Deprecated host entry point: co-execute a bare IR builder.
        Superseded by binding arguments on a
        :class:`~repro.core.program.Kernel` and calling :meth:`launch`
        — same split/merge machinery, plus typed argument validation
        and the program's shared plan tier."""
        warnings.warn(
            "CoExecutor.run(build, ...) is deprecated; create a "
            "Program/Kernel via Context and use CoExecutor.launch "
            "(docs/host_api.md)", DeprecationWarning, stacklevel=2)
        kernels = {d: self._kernel_for(d, build, local_size)
                   for d in self.devices}
        return self._co_run(kernels, local_size, global_size, buffers,
                            scalars, mode, weights)

    def _co_run(self, kernels: Dict[Device, object],
                local_size: Sequence[int],
                global_size: Sequence[int],
                buffers: Dict[str, Union[np.ndarray, SharedBuffer]],
                scalars: Optional[Dict[str, object]] = None,
                mode: str = "static",
                weights: Optional[Sequence[float]] = None,
                persist_key: Optional[str] = None
                ) -> Dict[str, np.ndarray]:
        """Split/merge engine behind :meth:`launch` (and the deprecated
        :meth:`run`): ``kernels`` maps each device to its specialized
        launchable.  Returns the merged output arrays (keyed like
        ``buffers``).  Plain ndarrays are wrapped in throwaway
        :class:`SharedBuffer`\\ s; SharedBuffers keep residency across
        calls.  ``mode`` is ``"static"`` (one weighted span per device),
        ``"steal"`` (shared chunk deque, self-scheduled) or
        ``"adaptive"`` (throughput-modeled HGuided splitter with
        straggler stealing).  ``persist_key`` is the kernel's IR hash;
        when set, adaptive mode warm-starts from and records per-class
        weights into the tuning table."""
        t0 = time.perf_counter()
        lsz = tuple(local_size) + (1,) * (3 - len(local_size))
        gsz = tuple(global_size) + (1,) * (3 - len(global_size))
        n_groups = int(np.prod([g // l for g, l in zip(gsz, lsz)]))
        shared: Dict[str, SharedBuffer] = {}
        throwaway: List[SharedBuffer] = []
        for nm, b in buffers.items():
            if isinstance(b, SharedBuffer):
                shared[nm] = b
            else:
                sb = SharedBuffer(b, nm, self.tracker)
                shared[nm] = sb
                throwaway.append(sb)
        base = {nm: sb.host for nm, sb in shared.items()}

        stats = CoExecStats()
        stats.mode = mode
        stats.n_groups = n_groups
        mig0 = self.tracker.migrations
        pmig0 = self.tracker.partial_migrations
        byte0 = self.tracker.bytes_migrated
        hit0 = self.tracker.hits

        partials: List[Tuple[Device, Dict[str, np.ndarray]]] = []
        plock = threading.Lock()

        def run_chunk(device: Device, lo: int, hi: int) -> None:
            if hi <= lo:
                return
            # the transfer commands below already moved stale spans;
            # device_array re-checks residency, so these are hits (and a
            # safety net if a transfer was skipped as clean)
            arrs = {nm: sb.device_array(device)
                    for nm, sb in shared.items()}
            out = kernels[device](arrs, global_size, scalars,
                                  group_range=(lo, hi))
            for nm, sb in shared.items():
                sb.store_local(device, out[nm])
            with plock:
                partials.append((device, out))
                name = device.info.name
                stats.chunks_per_device[name] = \
                    stats.chunks_per_device.get(name, 0) + 1
                stats.groups_per_device[name] = \
                    stats.groups_per_device.get(name, 0) + (hi - lo)

        # -- plan the split -----------------------------------------------------
        if mode == "static":
            shares = list(weights) if weights is not None \
                else [1.0] * len(self.devices)
            if len(shares) != len(self.devices):
                raise InvalidArgError(
                    f"static co-execution needs one weight per device: "
                    f"{len(shares)} weights for {len(self.devices)} devices")
            spans = split_groups(n_groups, shares)
            plan = [(dev, (lo, hi)) for dev, (lo, hi)
                    in zip(self.devices, spans) if hi > lo]
            active = [dev for dev, _ in plan]
        elif mode in ("steal", "adaptive"):
            plan = None
            active = list(self.devices)
        else:
            raise InvalidArgError(f"unknown co-execution mode {mode!r}")

        # -- event-ordered migration -------------------------------------------
        # each stale (buffer, device) pair becomes an explicit transfer
        # command on the destination queue; chunk commands depend on
        # their device's transfers, so migration to one device overlaps
        # with compute (and transfers) on the others instead of blocking
        # the enqueue path
        transfer_events: Dict[Device, List[Event]] = {d: [] for d in active}
        for dev in active:
            q = self.queues[dev]
            for nm, sb in shared.items():
                if sb.clean_on(dev):
                    continue
                ev = q.enqueue_native(
                    lambda s=sb, d=dev: s.migrate_to(d),
                    name=f"migrate:{nm}->{dev.info.name}",
                    kind="transfer")
                transfer_events[dev].append(ev)

        # -- enqueue chunk commands --------------------------------------------
        chunk_events: List[Event] = []
        elock = threading.Lock()
        splitter: Optional[AdaptiveSplitter] = None
        merge_gate: Optional[UserEvent] = None
        co_key: Optional[str] = None
        if mode == "static":
            for dev, (lo, hi) in plan:
                q = self.queues[dev]
                ev = q.enqueue_native(
                    lambda d=dev, a=lo, b=hi: run_chunk(d, a, b),
                    wait_for=transfer_events[dev],
                    name=f"co-chunk:{dev.info.name}:{lo}-{hi}",
                    kind="kernel")
                chunk_events.append(ev)
        elif mode == "steal":
            n_chunks = max(len(self.devices),
                           self.chunks_per_device * len(self.devices))
            chunk = -(-n_groups // n_chunks)  # ceil; whole work-groups
            todo = deque((lo, min(lo + chunk, n_groups))
                         for lo in range(0, n_groups, max(1, chunk)))
            # equal-split "territories" for steal accounting: a chunk a
            # device pulls from another device's territory is a steal
            own = split_groups(n_groups, [1.0] * len(self.devices)) \
                if n_groups else []

            def owner_of(lo: int) -> Optional[Device]:
                for d, (a, b) in zip(self.devices, own):
                    if a <= lo < b:
                        return d
                return None

            def drain(device: Device) -> None:
                while True:
                    try:
                        lo, hi = todo.popleft()
                    except IndexError:
                        return
                    run_chunk(device, lo, hi)
                    if owner_of(lo) is not device:
                        with plock:
                            nm = device.info.name
                            stats.steals_per_device[nm] = \
                                stats.steals_per_device.get(nm, 0) + 1

            for dev in self.devices:
                q = self.queues[dev]
                ev = q.enqueue_native(
                    lambda d=dev: drain(d),
                    wait_for=transfer_events[dev],
                    name=f"co-drain:{dev.info.name}",
                    kind="kernel")
                chunk_events.append(ev)
        else:  # adaptive: event-driven HGuided dispatch
            table = self._table()
            classes = [device_class(d) for d in self.devices]
            if persist_key:
                co_key = TuningTable.make_coexec_key(persist_key, classes)
                ent = table.get_coexec(co_key)
                if ent:
                    for d, cls in zip(self.devices, classes):
                        w = ent["weights"].get(cls)
                        if w is not None:
                            self.throughput.seed(d, w)
            splitter = AdaptiveSplitter(
                n_groups, self.devices, self.throughput,
                min_chunk=self.min_chunk_groups,
                divisor=self.hguided_divisor)
            # the merge waits on this gate, not on the chunk events: it
            # fires when completed spans first cover [0, n_groups), which
            # may be *before* a stalled straggler finishes its (stolen,
            # already re-executed) span
            merge_gate = UserEvent("co-adaptive-done")

            def on_chunk_done(ev: Event, device: Device,
                              span: Tuple[int, int]) -> None:
                if ev.failed:
                    merge_gate.fail(ev.error)  # merge sees DependencyError
                    return
                self.throughput.observe_event(device, span[1] - span[0], ev)
                if splitter.complete(device, span):
                    merge_gate.complete()
                elif not merge_gate.done:
                    dispatch(device)

            def dispatch(device: Device) -> None:
                span = splitter.next_chunk(device)
                if span is None:
                    return
                lo, hi = span
                q = self.queues[device]
                ev = q.enqueue_native(
                    lambda d=device, a=lo, b=hi: run_chunk(d, a, b),
                    wait_for=transfer_events[device],
                    name=f"co-adaptive:{device.info.name}:{lo}-{hi}",
                    kind="kernel")
                with elock:
                    chunk_events.append(ev)
                ev.add_callback(
                    lambda e, d=device, s=span: on_chunk_done(e, d, s))
                # callbacks enqueue after the launch-time flush below, so
                # every dynamic enqueue must arm its command itself
                q.flush()

            if splitter.finished:        # n_groups == 0: nothing to run
                merge_gate.complete()
            for dev in active:
                dispatch(dev)

        # the merge waits on every chunk event — across queues — then
        # folds each chunk's written elements into the canonical copy
        merged: Dict[str, np.ndarray] = {}

        def merge() -> None:
            # snapshot: in adaptive mode a stalled straggler (whose span
            # was stolen and already merged-in) may still be appending
            with plock:
                parts = list(partials)
            for nm, sb in shared.items():
                ref = base[nm]
                acc = ref.copy()
                itemsize = acc.dtype.itemsize
                written: Dict[Device, List] = {}
                exact = True
                for device, part in parts:
                    sub = np.asarray(part[nm])
                    mask = _changed_mask(sub, ref)
                    if mask.any():
                        acc[mask] = sub[mask]
                        spans = _mask_to_byte_spans(mask, itemsize)
                        if spans is None:
                            exact = False
                        else:
                            written.setdefault(device, []).extend(spans)
                merged[nm] = acc
                if written or not exact:
                    if exact:
                        # span-granular invalidation: each device stays
                        # valid over what it wrote itself and goes stale
                        # only over the spans other devices wrote
                        sb.commit_spans(acc, written)
                    else:
                        # a write pattern too scattered for exact spans:
                        # whole-buffer invalidate (always safe)
                        sb.commit(acc)

        q0 = self.queues[self.devices[0]]
        merge_deps = [merge_gate] if merge_gate is not None else chunk_events
        merge_ev = q0.enqueue_native(merge, wait_for=merge_deps,
                                     name="co-merge")
        for q in self.queues.values():
            q.flush()
        try:
            merge_ev.wait()
        finally:
            with elock:
                evs = list(chunk_events)
            stragglers = [e for e in evs if not e.done]
            if throwaway and stragglers:
                # a stolen straggler is still executing against the
                # throwaway device buffers: release once it lands, off
                # the launch's critical path (its result is already
                # merged — purity makes the duplicate bitwise-identical)
                def release_when_idle(evs=evs):
                    for e in evs:
                        e._terminal.wait(60.0)
                    for sb in throwaway:
                        sb.release()
                q0.enqueue_native(release_when_idle, name="co-release")
                q0.flush()
            else:
                for sb in throwaway:  # one-shot wrappers: free chunks
                    sb.release()

        if splitter is not None:
            for d in self.devices:
                nm = d.info.name
                stats.steals_per_device[nm] = splitter.steals[d]
            stats.weights = {
                d.info.name: w for d, w in
                zip(self.devices, self.throughput.weights(self.devices))}
            if co_key is not None:
                # persist per *class*: same-class devices share (average)
                cls_w: Dict[str, List[float]] = {}
                for d in self.devices:
                    cls_w.setdefault(device_class(d), []).append(
                        stats.weights[d.info.name])
                self._table().record_coexec(
                    co_key, {c: sum(v) / len(v) for c, v in cls_w.items()})
        stats.events = chunk_events + [merge_ev]
        stats.transfer_events = [e for evs_ in transfer_events.values()
                                 for e in evs_]
        stats.migrations = self.tracker.migrations - mig0
        stats.partial_migrations = self.tracker.partial_migrations - pmig0
        stats.bytes_migrated = self.tracker.bytes_migrated - byte0
        stats.residency_hits = self.tracker.hits - hit0
        stats.wall_s = time.perf_counter() - t0
        self.last_stats = stats
        return merged

    def finish(self) -> None:
        """Drain every per-device queue (clFinish over the device set)."""
        for q in self.queues.values():
            q.finish()
