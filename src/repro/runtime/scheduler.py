"""Multi-device co-execution of one NDRange (docs/runtime.md §Scheduler).

pocl schedules a kernel launch onto *one* device; co-execution engines
(EngineCL, Nozal et al. — PAPERS.md) show that splitting a single NDRange
across heterogeneous devices is where platform portability becomes
throughput.  This module fans one launch out over several
:class:`~repro.runtime.platform.Device`s:

* the NDRange is split along the **linearized work-group axis** into
  contiguous ``group_range`` chunks (work-groups are the only unit OpenCL
  lets you split on: no cross-group synchronization exists);
* **static** mode pre-assigns one contiguous span per device, sized by
  ``weights`` (compute-power ratios, default equal);
* **steal** mode enqueues many small chunks into a shared deque and lets
  each device's drain command pull the next chunk whenever it finishes
  one — self-scheduling, so a slow device simply takes fewer chunks;
* every chunk launch goes through the device's own
  :class:`~repro.runtime.queue.CommandQueue`, so chunk commands carry
  events with full profiling, and the final merge command *waits on all
  chunk events across queues* — a cross-queue event DAG;
* buffer movement is tracked by a
  :class:`~repro.runtime.bufalloc.ResidencyTracker`: a
  :class:`SharedBuffer` is copied to a device on first use and then stays
  resident until some launch writes it, so N chunk launches on one device
  trigger exactly one migration.

Results are **bitwise identical** to a single-device launch of the same
target: a ``group_range`` sub-launch executes exactly the same group ids
with the same group-id decoding, and merging takes each element from the
chunk that wrote it.  (Merging assumes the OpenCL data-race rule already
required for independent commands: distinct work-groups write disjoint
elements.)
"""

from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .bufalloc import ResidencyTracker
from .platform import Buffer, Device, create_buffer
from .queue import CommandQueue, Event

_buf_ids = itertools.count()


class SharedBuffer:
    """A buffer logically shared by several devices (cl_mem used from
    multiple queues).

    The canonical copy lives on the host (``self.host``); each device
    gets a lazily-allocated :class:`~repro.runtime.platform.Buffer` from
    its own Bufalloc arena, filled on first use and kept valid across
    launches by the residency tracker.  ``commit`` installs a new
    canonical value (after a merge) and invalidates every device copy.
    """

    def __init__(self, host: np.ndarray, name: str,
                 tracker: ResidencyTracker):
        self.host = np.asarray(host)
        self.name = name
        # residency is keyed by a per-instance nonce, not the user-chosen
        # name: two SharedBuffers reusing a name on one tracker must not
        # alias each other's residency state (stale device data)
        self._key = f"{name}#{next(_buf_ids)}"
        self.tracker = tracker
        self._device_bufs: Dict[Device, Buffer] = {}
        self._lock = threading.Lock()

    def device_array(self, device: Device) -> np.ndarray:
        """The device-resident copy, migrating host -> device if stale.

        Safe to call from concurrent chunk commands: the copy happens at
        most once per (buffer, device) between writes."""
        with self._lock:
            buf = self._device_bufs.get(device)
            if buf is None:
                buf = create_buffer(device, self.host.size,
                                    str(self.host.dtype))
                self._device_bufs[device] = buf
            if self.tracker.acquire(self._key, device):
                buf.data = self.host.copy()
            return buf.data

    def commit(self, merged: np.ndarray) -> None:
        """Install a merged result as the canonical host copy; all device
        copies become stale (the next read on any device re-migrates)."""
        with self._lock:
            self.host = np.asarray(merged)
            self.tracker.wrote(self._key, "host")

    def release(self) -> None:
        """Free every device-side chunk and forget residency."""
        with self._lock:
            for buf in self._device_bufs.values():
                buf.release()
            self._device_bufs.clear()
            self.tracker.drop(self._key)


def split_groups(n_groups: int, shares: Sequence[float]
                 ) -> List[Tuple[int, int]]:
    """Split ``[0, n_groups)`` into contiguous spans proportional to
    ``shares`` (one span per share; empty spans allowed at the tail)."""
    total = float(sum(shares))
    assert total > 0, "shares must sum > 0"
    bounds = [0]
    acc = 0.0
    for s in shares[:-1]:
        acc += s
        bounds.append(min(n_groups, round(n_groups * acc / total)))
    bounds.append(n_groups)
    # enforce monotonicity after rounding
    for i in range(1, len(bounds)):
        bounds[i] = max(bounds[i], bounds[i - 1])
    return [(bounds[i], bounds[i + 1]) for i in range(len(shares))]


class CoExecStats:
    """What one co-executed launch did: chunks and groups per device,
    events (with profiling), migrations, and wall time."""

    def __init__(self) -> None:
        self.mode = ""
        self.n_groups = 0
        self.chunks_per_device: Dict[str, int] = {}
        self.groups_per_device: Dict[str, int] = {}
        self.events: List[Event] = []
        self.migrations = 0
        self.residency_hits = 0
        self.wall_s = 0.0

    def as_dict(self) -> Dict[str, object]:
        return {"mode": self.mode, "n_groups": self.n_groups,
                "chunks_per_device": dict(self.chunks_per_device),
                "groups_per_device": dict(self.groups_per_device),
                "migrations": self.migrations,
                "residency_hits": self.residency_hits,
                "wall_s": self.wall_s}


class CoExecutor:
    """Fans ND-range launches out across multiple devices.

    Parameters
    ----------
    devices:
        The participating devices; each gets a private out-of-order
        :class:`CommandQueue`.
    chunks_per_device:
        Granularity of the ``steal`` mode: the NDRange is cut into
        ``chunks_per_device * len(devices)`` chunks for self-scheduling.
    """

    def __init__(self, devices: Sequence[Device],
                 chunks_per_device: int = 4):
        assert devices, "CoExecutor needs at least one device"
        self.devices = list(devices)
        self.chunks_per_device = chunks_per_device
        self.tracker = ResidencyTracker()
        self.queues = {d: CommandQueue(d, out_of_order=True, workers=2)
                       for d in self.devices}
        self._kernels: Dict[tuple, object] = {}
        self.last_stats: Optional[CoExecStats] = None

    # -- buffers ---------------------------------------------------------------
    def shared_buffer(self, host: np.ndarray, name: str) -> SharedBuffer:
        """Wrap a host array for residency-tracked multi-device use.
        Reusing the SharedBuffer across ``run`` calls is what makes
        repeat launches migration-free."""
        return SharedBuffer(host, name, self.tracker)

    # -- kernel compilation (per device: enqueue-time specialization) ----------
    def _kernel_for(self, device: Device, build: Callable,
                    local_size: Sequence[int]):
        key = (device, build, tuple(local_size))
        k = self._kernels.get(key)
        if k is None:
            k = device.build_kernel(build, local_size)
            self._kernels[key] = k
        return k

    # -- the co-executed launch -------------------------------------------------
    def run(self, build: Callable, local_size: Sequence[int],
            global_size: Sequence[int],
            buffers: Dict[str, Union[np.ndarray, SharedBuffer]],
            scalars: Optional[Dict[str, object]] = None,
            mode: str = "static",
            weights: Optional[Sequence[float]] = None
            ) -> Dict[str, np.ndarray]:
        """Launch ``build`` over ``global_size``, co-executed.

        Returns the merged output arrays (keyed like ``buffers``).  Plain
        ndarrays are wrapped in throwaway :class:`SharedBuffer`s; pass
        SharedBuffers (see :meth:`shared_buffer`) to keep residency
        across calls.  ``mode`` is ``"static"`` (one weighted span per
        device) or ``"steal"`` (shared chunk deque, self-scheduled).
        """
        t0 = time.perf_counter()
        lsz = tuple(local_size) + (1,) * (3 - len(local_size))
        gsz = tuple(global_size) + (1,) * (3 - len(global_size))
        n_groups = int(np.prod([g // l for g, l in zip(gsz, lsz)]))
        shared: Dict[str, SharedBuffer] = {}
        throwaway: List[SharedBuffer] = []
        for nm, b in buffers.items():
            if isinstance(b, SharedBuffer):
                shared[nm] = b
            else:
                sb = SharedBuffer(b, nm, self.tracker)
                shared[nm] = sb
                throwaway.append(sb)
        base = {nm: sb.host for nm, sb in shared.items()}

        kernels = {d: self._kernel_for(d, build, local_size)
                   for d in self.devices}
        stats = CoExecStats()
        stats.mode = mode
        stats.n_groups = n_groups
        mig0 = self.tracker.migrations
        hit0 = self.tracker.hits

        partials: List[Dict[str, np.ndarray]] = []
        plock = threading.Lock()

        def run_chunk(device: Device, lo: int, hi: int) -> None:
            if hi <= lo:
                return
            arrs = {nm: sb.device_array(device)
                    for nm, sb in shared.items()}
            out = kernels[device](arrs, global_size, scalars,
                                  group_range=(lo, hi))
            with plock:
                partials.append(out)
                name = device.info.name
                stats.chunks_per_device[name] = \
                    stats.chunks_per_device.get(name, 0) + 1
                stats.groups_per_device[name] = \
                    stats.groups_per_device.get(name, 0) + (hi - lo)

        chunk_events: List[Event] = []
        if mode == "static":
            shares = list(weights) if weights is not None \
                else [1.0] * len(self.devices)
            assert len(shares) == len(self.devices), \
                "one weight per device"
            spans = split_groups(n_groups, shares)
            for dev, (lo, hi) in zip(self.devices, spans):
                if hi <= lo:
                    continue
                q = self.queues[dev]
                ev = q.enqueue_native(
                    lambda d=dev, a=lo, b=hi: run_chunk(d, a, b),
                    name=f"co-chunk:{dev.info.name}:{lo}-{hi}")
                chunk_events.append(ev)
        elif mode == "steal":
            n_chunks = max(len(self.devices),
                           self.chunks_per_device * len(self.devices))
            chunk = -(-n_groups // n_chunks)  # ceil; whole work-groups
            todo = deque((lo, min(lo + chunk, n_groups))
                         for lo in range(0, n_groups, max(1, chunk)))

            def drain(device: Device) -> None:
                while True:
                    try:
                        lo, hi = todo.popleft()
                    except IndexError:
                        return
                    run_chunk(device, lo, hi)

            for dev in self.devices:
                q = self.queues[dev]
                ev = q.enqueue_native(
                    lambda d=dev: drain(d),
                    name=f"co-drain:{dev.info.name}")
                chunk_events.append(ev)
        else:
            raise ValueError(f"unknown co-execution mode {mode!r}")

        # the merge waits on every chunk event — across queues — then
        # folds each chunk's written elements into the canonical copy
        merged: Dict[str, np.ndarray] = {}

        def merge() -> None:
            for nm, sb in shared.items():
                ref = base[nm]
                acc = ref.copy()
                wrote = False
                for part in partials:
                    sub = np.asarray(part[nm])
                    mask = sub != ref
                    if mask.any():
                        acc[mask] = sub[mask]
                        wrote = True
                merged[nm] = acc
                if wrote:
                    sb.commit(acc)

        q0 = self.queues[self.devices[0]]
        merge_ev = q0.enqueue_native(merge, wait_for=chunk_events,
                                     name="co-merge")
        for q in self.queues.values():
            q.flush()
        try:
            merge_ev.wait()
        finally:
            for sb in throwaway:  # one-shot wrappers: free device chunks
                sb.release()

        stats.events = chunk_events + [merge_ev]
        stats.migrations = self.tracker.migrations - mig0
        stats.residency_hits = self.tracker.hits - hit0
        stats.wall_s = time.perf_counter() - t0
        self.last_stats = stats
        return merged

    def finish(self) -> None:
        """Drain every per-device queue (clFinish over the device set)."""
        for q in self.queues.values():
            q.finish()
