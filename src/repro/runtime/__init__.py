"""OpenCL-shaped runtime: host layer over the device layer (paper §3).

Layering (docs/runtime.md, docs/memory.md, docs/host_api.md):

  context.py    — Context: the host object-model root (shared caches,
                  pooled allocation, programs/kernels/queues)
  events.py     — Event / UserEvent: status ladder + profiling counters
  queue.py      — CommandQueue: the event-DAG scheduler per device
  scheduler.py  — CoExecutor: one NDRange split across several devices
  platform.py   — Platform / Device / Buffer (clGetPlatformIDs et al.)
  bufalloc.py   — the pocl buffer allocator + span-granular residency
  memory.py     — sub-buffers, zero-copy map/unmap, size-class pooling
  trace.py      — ChromeTrace: event-DAG export for chrome://tracing
"""

from ..core.errors import (BuildError, InvalidArgError, InvalidBufferError,
                           ReproError, status_name)
from ..core.program import Kernel, Program
from .bufalloc import Bufalloc, OutOfMemory, ResidencyTracker
from .context import Context, default_context
from .events import (CommandError, DependencyError, Event, EventStatus,
                     UserEvent, chunk_counters, wait_for_events)
from .memory import (MAP_READ, MAP_READ_WRITE, MAP_WRITE,
                     MAP_WRITE_INVALIDATE, BufferPool, MapError,
                     MappedRegion, SubBuffer, create_sub_buffer)
from .platform import (Buffer, Device, DeviceInfo, Platform,
                       ThrottledDevice, create_buffer, default_platform)
from .queue import CommandQueue
from .scheduler import (AdaptiveSplitter, CoExecStats, CoExecutor,
                        SharedBuffer, ThroughputModel, device_class,
                        split_groups)
from .trace import ChromeTrace, validate_trace

__all__ = [
    "Context", "default_context", "Program", "Kernel",
    "ReproError", "InvalidArgError", "InvalidBufferError", "BuildError",
    "status_name",
    "Bufalloc", "OutOfMemory", "ResidencyTracker",
    "Event", "EventStatus", "UserEvent", "CommandError", "DependencyError",
    "wait_for_events", "chunk_counters",
    "Platform", "Device", "DeviceInfo", "ThrottledDevice", "Buffer",
    "create_buffer", "default_platform",
    "CommandQueue",
    "CoExecutor", "CoExecStats", "SharedBuffer", "split_groups",
    "ThroughputModel", "AdaptiveSplitter", "device_class",
    "MapError", "MappedRegion", "SubBuffer", "create_sub_buffer",
    "BufferPool", "MAP_READ", "MAP_WRITE", "MAP_READ_WRITE",
    "MAP_WRITE_INVALIDATE",
    "ChromeTrace", "validate_trace",
]
