"""OpenCL-shaped runtime: host layer over the device layer (paper §3)."""

from .bufalloc import Bufalloc, OutOfMemory
from .platform import Buffer, Device, DeviceInfo, Platform, create_buffer
from .queue import CommandQueue, Event

__all__ = ["Bufalloc", "OutOfMemory", "Platform", "Device", "DeviceInfo",
           "Buffer", "create_buffer", "CommandQueue", "Event"]
