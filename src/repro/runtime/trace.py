"""Chrome-trace export of the event DAG (docs/mesh.md §Observability).

Every :class:`~repro.runtime.events.Event` already carries the four
``clGetEventProfilingInfo`` counters (``queued_ns / submit_ns /
start_ns / end_ns``), a ``kind`` (the CL_EVENT_COMMAND_TYPE analogue)
and ``fused_from`` provenance.  This module turns a run's events into
the Chrome Trace Event Format (the ``chrome://tracing`` /
https://ui.perfetto.dev JSON), so a production operator can *see* queue
depth, prefill/decode overlap, fusion, and migration stalls per request
instead of reading counters:

* one **process** row per device (or serving replica), one **thread**
  row per command queue — ``ph:"X"`` complete slices spanning
  RUNNING→terminal, with the full profile counters in ``args``;
* **flow arrows** (``ph:"s"``/``ph:"f"``) for every DAG dependency edge
  between recorded events, and for cross-replica request *migrations*
  (emitted by the serving mesh);
* **counter tracks** (``ph:"C"``) for per-queue depth (derived from the
  recorded events — no sampling thread) plus any caller-fed series
  (the serving engines feed ``kv_pages_live``);
* ``ph:"M"`` metadata naming every process/thread row.

Collection is push-based and cheap: :meth:`ChromeTrace.attach_queue`
installs the collector as the queue's ``trace_sink``; the queue calls
:meth:`on_command` once per enqueued command (fused super-commands
included), and everything else — timestamps, status, provenance — is
read off the events at export time.  :func:`validate_trace` is the
schema gate (required fields per phase, monotone/non-negative
timestamps, flow-event pairing) shared by tests/test_trace.py, the
bench_mesh CI gate, and the docs-job check.

Entry points: ``Context.trace()`` wraps a host-API region
(docs/host_api.md), ``ServingMesh.attach_trace`` wires a whole replica
mesh, and ``launch/serve.py --trace out.json`` records a serving run.
"""

from __future__ import annotations

import itertools
import json
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

from .events import Event

__all__ = ["ChromeTrace", "validate_trace"]

_flow_ids = itertools.count(1)


class ChromeTrace:
    """Collects events (live, via queue ``trace_sink``) plus manual
    instants / flows / counters, and exports Chrome-trace JSON.

    Processes and threads are named, not numbered: every API takes a
    ``process`` (device / replica) and optional ``thread`` (queue) name
    and the collector assigns stable integer pid/tid values, emitting
    ``process_name`` / ``thread_name`` metadata at export."""

    def __init__(self, name: str = "repro"):
        self.name = name
        self._lock = threading.Lock()
        self._pids: Dict[str, int] = {}
        self._tids: Dict[Tuple[int, str], int] = {}
        # (event, dep events snapshot, pid, tid) per recorded command
        self._commands: List[Tuple[Event, Tuple[Event, ...], int, int]] = []
        self._track: Dict[int, Tuple[int, int]] = {}   # event id -> pid/tid
        self._rows: Dict[int, Tuple[int, int]] = {}    # id(queue) -> pid/tid
        self._extra: List[dict] = []                   # manual raw events
        self._queues: List[object] = []

    # -- naming ---------------------------------------------------------------
    def _pid(self, process: str) -> int:
        pid = self._pids.get(process)
        if pid is None:
            pid = len(self._pids) + 1
            self._pids[process] = pid
        return pid

    def _tid(self, pid: int, thread: str) -> int:
        tid = self._tids.get((pid, thread))
        if tid is None:
            tid = sum(1 for (p, _t) in self._tids if p == pid) + 1
            self._tids[(pid, thread)] = tid
        return tid

    # -- live collection ------------------------------------------------------
    def attach_queue(self, queue, process: Optional[str] = None,
                     thread: Optional[str] = None) -> None:
        """Install this collector as ``queue.trace_sink``.  One trace
        row per device queue: ``process`` defaults to the queue's device
        name, ``thread`` to ``queue<N>`` within that process."""
        with self._lock:
            pid = self._pid(process or queue.device.info.name)
            if thread is None:
                thread = f"queue{sum(1 for (p, _t) in self._tids if p == pid)}"
            self._rows[id(queue)] = (pid, self._tid(pid, thread))
            self._queues.append(queue)
        queue.trace_sink = self

    def detach_all(self) -> None:
        """Stop collecting from every attached queue (recorded events
        stay; export still works)."""
        with self._lock:
            queues, self._queues = self._queues, []
        for q in queues:
            if q.trace_sink is self:
                q.trace_sink = None

    def on_command(self, event: Event, deps: Sequence[Event],
                   queue) -> None:
        """Queue sink protocol: called once per enqueued command (and
        once per fused super-command) with its resolved wait list."""
        with self._lock:
            row = self._rows.get(id(queue))
            if row is None:        # queue never attached: own device row
                pid = self._pid(queue.device.info.name)
                row = (pid, self._tid(pid, "queue"))
                self._rows[id(queue)] = row
            pid, tid = row
            self._commands.append((event, tuple(deps), pid, tid))
            self._track[event.id] = (pid, tid)

    # -- manual events --------------------------------------------------------
    def instant(self, name: str, process: str,
                thread: Optional[str] = None,
                ts_ns: Optional[int] = None,
                args: Optional[dict] = None) -> Tuple[int, int, int]:
        """An ``ph:"i"`` instant marker; returns ``(pid, tid, ts_ns)``
        so callers can anchor flow arrows on it."""
        ts = time.monotonic_ns() if ts_ns is None else int(ts_ns)
        with self._lock:
            pid = self._pid(process)
            tid = self._tid(pid, thread or "events")
            self._extra.append({"ph": "i", "name": name, "s": "t",
                                "pid": pid, "tid": tid, "_ts_ns": ts,
                                "args": args or {}})
        return pid, tid, ts

    def flow(self, name: str, src: Tuple[int, int, int],
             dst: Tuple[int, int, int], cat: str = "migration") -> int:
        """A paired ``ph:"s"`` → ``ph:"f"`` flow arrow between two
        ``(pid, tid, ts_ns)`` anchors (e.g. two :meth:`instant`
        results).  Returns the flow id."""
        fid = next(_flow_ids)
        s_pid, s_tid, s_ts = src
        d_pid, d_tid, d_ts = dst
        with self._lock:
            self._extra.append({"ph": "s", "name": name, "cat": cat,
                                "id": fid, "pid": s_pid, "tid": s_tid,
                                "_ts_ns": int(s_ts)})
            self._extra.append({"ph": "f", "bp": "e", "name": name,
                                "cat": cat, "id": fid, "pid": d_pid,
                                "tid": d_tid,
                                "_ts_ns": max(int(d_ts), int(s_ts))})
        return fid

    def counter(self, name: str, value, process: str,
                ts_ns: Optional[int] = None) -> None:
        """One sample of a ``ph:"C"`` counter track (e.g. the serving
        engine's ``kv_pages_live``)."""
        ts = time.monotonic_ns() if ts_ns is None else int(ts_ns)
        with self._lock:
            pid = self._pid(process)
            self._extra.append({"ph": "C", "name": name, "pid": pid,
                                "tid": 0, "_ts_ns": ts,
                                "args": {"value": value}})

    # -- export ---------------------------------------------------------------
    def trace_events(self) -> List[dict]:
        """The ``traceEvents`` list: metadata + slices + DAG flows +
        derived queue-depth counters + manual events, sorted by ``ts``
        (microseconds relative to the earliest recorded timestamp)."""
        with self._lock:
            commands = list(self._commands)
            extra = [dict(e) for e in self._extra]
            pids = dict(self._pids)
            tids = dict(self._tids)
            track = dict(self._track)

        done = [(ev, deps, pid, tid) for ev, deps, pid, tid in commands
                if ev.done and ev.queued_ns is not None
                and ev.start_ns is not None and ev.end_ns is not None]
        stamps = [ev.queued_ns for ev, *_ in done]
        stamps += [e["_ts_ns"] for e in extra]
        t0 = min(stamps) if stamps else 0

        def us(ns: int) -> float:
            return max(0, ns - t0) / 1e3

        out: List[dict] = []
        for name, pid in sorted(pids.items(), key=lambda kv: kv[1]):
            out.append({"ph": "M", "name": "process_name", "pid": pid,
                        "tid": 0, "ts": 0,
                        "args": {"name": name}})
        for (pid, tname), tid in sorted(tids.items(),
                                        key=lambda kv: kv[1]):
            out.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tid, "ts": 0, "args": {"name": tname}})

        depth_marks: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
        for ev, deps, pid, tid in done:
            args = {"kind": ev.kind, "ok": ev.succeeded,
                    "status": ev.status,
                    "queued_ns": ev.queued_ns, "submit_ns": ev.submit_ns,
                    "start_ns": ev.start_ns, "end_ns": ev.end_ns,
                    "queue_us": round((ev.start_ns - ev.queued_ns) / 1e3,
                                      3)}
            if ev.fused_from:
                args["fused_from"] = [o.name for o in ev.fused_from]
            if ev.error is not None:
                args["error"] = f"{type(ev.error).__name__}: {ev.error}"
            out.append({"ph": "X", "name": ev.name, "cat": ev.kind,
                        "pid": pid, "tid": tid, "ts": us(ev.start_ns),
                        "dur": max(0, ev.end_ns - ev.start_ns) / 1e3,
                        "args": args})
            marks = depth_marks.setdefault((pid, tid), [])
            marks.append((ev.queued_ns, 1))
            marks.append((ev.end_ns, -1))
            # DAG edges: dep end -> this command's start, on the tracks
            # that recorded both ends
            for dep in deps:
                src = track.get(dep.id)
                if src is None or not dep.done or dep.end_ns is None:
                    continue
                fid = next(_flow_ids)
                out.append({"ph": "s", "name": "dag", "cat": "dag",
                            "id": fid, "pid": src[0], "tid": src[1],
                            "ts": us(dep.end_ns)})
                out.append({"ph": "f", "bp": "e", "name": "dag",
                            "cat": "dag", "id": fid, "pid": pid,
                            "tid": tid,
                            "ts": us(max(ev.start_ns, dep.end_ns))})

        # queue depth: derived counter per (pid, tid), no sampling thread
        for (pid, tid), marks in sorted(depth_marks.items()):
            depth = 0
            for ts_ns, delta in sorted(marks):
                depth += delta
                out.append({"ph": "C", "name": f"queue_depth t{tid}",
                            "pid": pid, "tid": 0, "ts": us(ts_ns),
                            "args": {"value": depth}})

        for e in extra:
            e["ts"] = us(e.pop("_ts_ns"))
            out.append(e)

        out.sort(key=lambda e: (e["ts"], 0 if e["ph"] == "M" else 1))
        return out

    def export(self, path: str) -> dict:
        """Write the full Chrome-trace JSON object to ``path`` (load it
        in ``chrome://tracing`` or https://ui.perfetto.dev) and return
        it."""
        doc = {"traceEvents": self.trace_events(),
               "displayTimeUnit": "ms",
               "otherData": {"producer": f"repro:{self.name}"}}
        with open(path, "w") as f:
            json.dump(doc, f, indent=1, default=float)
        return doc


# ---------------------------------------------------------------------------
# schema validation (the golden gate shared by tests / bench / docs job)
# ---------------------------------------------------------------------------

_REQUIRED = {"M": ("name", "pid", "tid", "args"),
             "X": ("name", "pid", "tid", "ts", "dur"),
             "C": ("name", "pid", "ts", "args"),
             "i": ("name", "pid", "tid", "ts"),
             "s": ("name", "id", "pid", "tid", "ts"),
             "f": ("name", "id", "pid", "tid", "ts")}


def validate_trace(events: List[dict]) -> Dict[str, int]:
    """Validate a ``traceEvents`` list against the Chrome Trace Event
    Format subset this exporter emits.  Checks, raising ``ValueError``
    with the offending event on the first violation:

    * every event has a known ``ph`` and that phase's required fields;
    * timestamps are non-negative and ``X`` durations non-negative;
    * every flow start (``ph:"s"``) pairs with exactly one flow finish
      (``ph:"f"``) of the same ``id``, and the finish is not earlier;
    * every ``pid``/``tid`` used by a slice is named by ``M`` metadata.

    Returns per-phase event counts (the golden-schema test snapshots a
    normalized skeleton on top of this)."""
    counts: Dict[str, int] = {}
    named_pids = set()
    named_tids = set()
    starts: Dict[object, dict] = {}
    finishes: Dict[object, dict] = {}
    for e in events:
        ph = e.get("ph")
        if ph not in _REQUIRED:
            raise ValueError(f"unknown ph in trace event: {e}")
        for field in _REQUIRED[ph]:
            if field not in e:
                raise ValueError(f"trace event missing {field!r}: {e}")
        counts[ph] = counts.get(ph, 0) + 1
        if ph != "M":
            if e["ts"] < 0:
                raise ValueError(f"negative ts: {e}")
        if ph == "X":
            if e["dur"] < 0:
                raise ValueError(f"negative dur: {e}")
        if ph == "M":
            if e["name"] == "process_name":
                named_pids.add(e["pid"])
            elif e["name"] == "thread_name":
                named_tids.add((e["pid"], e["tid"]))
        elif ph == "s":
            if e["id"] in starts:
                raise ValueError(f"duplicate flow start id {e['id']}")
            starts[e["id"]] = e
        elif ph == "f":
            if e["id"] in finishes:
                raise ValueError(f"duplicate flow finish id {e['id']}")
            finishes[e["id"]] = e
    for fid, s in starts.items():
        f = finishes.get(fid)
        if f is None:
            raise ValueError(f"flow start {fid} has no finish: {s}")
        if f["ts"] < s["ts"]:
            raise ValueError(
                f"flow {fid} finishes before it starts: {s} -> {f}")
    for fid in finishes:
        if fid not in starts:
            raise ValueError(f"flow finish {fid} has no start")
    for e in events:
        if e["ph"] in ("X", "i"):
            if e["pid"] not in named_pids:
                raise ValueError(f"slice on unnamed pid: {e}")
            if (e["pid"], e["tid"]) not in named_tids:
                raise ValueError(f"slice on unnamed tid: {e}")
    return counts
