"""First-class events: the cl_event analogue (paper §3, docs/runtime.md).

Every enqueue operation returns an :class:`Event` that moves through the
OpenCL execution-status ladder

    QUEUED -> SUBMITTED -> RUNNING -> COMPLETE        (CL_QUEUED..CL_COMPLETE)

recording a monotonic nanosecond timestamp at each transition — the
``CL_PROFILING_COMMAND_{QUEUED,SUBMIT,START,END}`` counters of
``clGetEventProfilingInfo``.  A command that raises is *terminated with an
error* (OpenCL's negative execution status); waiters observe the exception
and dependent commands fail with :class:`DependencyError` instead of
running — error propagation along the event DAG.

Events are the edges of the runtime's dependency DAG: the command queue
(:mod:`repro.runtime.queue`) resolves ``wait_for`` lists through
:meth:`Event.add_callback`, which fires exactly once when the event reaches
a terminal state (immediately, if it already has).  Because an event exists
before anything can wait on it, the graph is acyclic by construction.

:class:`UserEvent` is the ``clCreateUserEvent`` analogue: host code gates
enqueued commands on an event it completes explicitly.
"""

from __future__ import annotations

import enum
import itertools
import threading
import time
from typing import Callable, Dict, List, Optional

from ..core.errors import ReproError, register_error

_event_ids = itertools.count()


class EventStatus(enum.IntEnum):
    """OpenCL command execution status (numeric values mirror CL_*)."""

    QUEUED = 3      # command is in a queue, not yet submitted for execution
    SUBMITTED = 2   # dependencies resolved; handed to a device worker
    RUNNING = 1     # command function is executing
    COMPLETE = 0    # finished successfully

    # errors are represented separately (Event.error); Event.status returns
    # a negative int for terminated commands, matching OpenCL's convention


#: status of a command terminated by an error (OpenCL: any negative value)
ERROR_STATUS = -1


@register_error
class CommandError(ReproError, RuntimeError):
    """A command's function raised; the original exception is ``__cause__``.
    Part of the typed :class:`~repro.core.errors.ReproError` hierarchy;
    a failed event's ``status`` surfaces the error's ``code`` (OpenCL's
    negative-status convention)."""

    code = -9998
    code_name = "REPRO_COMMAND_FAILED"


@register_error
class DependencyError(CommandError):
    """A command was abandoned because one of its wait-list events failed
    (CL_EXEC_STATUS_ERROR_FOR_EVENTS_IN_WAIT_LIST)."""

    code = -14
    code_name = "CL_EXEC_STATUS_ERROR_FOR_EVENTS_IN_WAIT_LIST"


class Event:
    """A future for one enqueued command, with status + profiling info.

    Attributes
    ----------
    queued_ns, submit_ns, start_ns, end_ns:
        ``time.monotonic_ns()`` captured at each status transition (the
        clGetEventProfilingInfo counters).  ``None`` until the transition
        happens; monotonically non-decreasing in transition order.
    error:
        The exception that terminated the command, or ``None``.
    kind:
        The command class this event belongs to — the
        ``CL_EVENT_COMMAND_TYPE`` analogue: ``"kernel"`` (NDRange
        launches), ``"transfer"`` (buffer reads/writes/migrations),
        ``"map"`` (map/unmap), ``"marker"``, ``"native"``, ``"user"``,
        or the generic ``"command"``.  The scheduler and the memory
        benchmark use it to attribute profile windows to migration vs
        compute (docs/memory.md §Migration).
    fused_from:
        Provenance for the DAG fusion rewrite (docs/runtime.md §Kernel
        fusion): the original per-kernel events a fused super-command
        replaced, in chain order.  Empty for ordinary commands.  The
        originals remain live DAG nodes (dependents wait on them; they
        complete when the fused command does), and ``finish(timeout)``
        expands this list when naming a stuck command.
    """

    def __init__(self, name: str, queue: Optional[object] = None,
                 kind: str = "command"):
        self.id = next(_event_ids)
        self.name = name
        self.queue = queue
        self.kind = kind
        self.fused_from: List["Event"] = []
        self.error: Optional[BaseException] = None
        self.queued_ns: Optional[int] = time.monotonic_ns()
        self.submit_ns: Optional[int] = None
        self.start_ns: Optional[int] = None
        self.end_ns: Optional[int] = None
        self._status: EventStatus = EventStatus.QUEUED
        self._terminal = threading.Event()
        self._lock = threading.Lock()
        self._callbacks: List[Callable[["Event"], None]] = []

    # -- status ---------------------------------------------------------------
    @property
    def status(self) -> int:
        """Current execution status; negative once terminated by an
        error — the typed :class:`~repro.core.errors.ReproError` code
        when the failure carries one (e.g. -14 for a DependencyError),
        else the generic :data:`ERROR_STATUS`."""
        if self.error is not None:
            code = getattr(self.error, "code", ERROR_STATUS)
            return int(code) if int(code) < 0 else ERROR_STATUS
        return int(self._status)

    @property
    def done(self) -> bool:
        """True once the event reached a terminal state (success or error)."""
        return self._terminal.is_set()

    @property
    def succeeded(self) -> bool:
        return self.done and self.error is None

    @property
    def failed(self) -> bool:
        return self.done and self.error is not None

    # -- transitions (called by the owning queue) ------------------------------
    def _transition(self, status: EventStatus) -> None:
        """Advance the status ladder, stamping the profiling counter."""
        now = time.monotonic_ns()
        fire = False
        with self._lock:
            assert int(status) < int(self._status), \
                f"event {self.name}: illegal transition " \
                f"{self._status.name} -> {status.name}"
            self._status = status
            if status is EventStatus.SUBMITTED:
                self.submit_ns = now
            elif status is EventStatus.RUNNING:
                self.start_ns = now
            elif status is EventStatus.COMPLETE:
                self.end_ns = now
                fire = True
        if fire:
            self._finish()

    def complete(self) -> None:
        """Mark the command complete (terminal, successful).

        Called by the queue when the command function returns; user code
        only calls this on :class:`UserEvent`.
        """
        now = time.monotonic_ns()
        with self._lock:
            if self._terminal.is_set():
                return
            self._status = EventStatus.COMPLETE
            if self.submit_ns is None:
                self.submit_ns = now
            if self.start_ns is None:
                self.start_ns = now
            self.end_ns = now
        self._finish()

    def fail(self, error: BaseException) -> None:
        """Terminate the command with an error (negative OpenCL status)."""
        now = time.monotonic_ns()
        with self._lock:
            if self._terminal.is_set():
                return
            self.error = error
            if self.submit_ns is None:
                self.submit_ns = now
            if self.start_ns is None:
                self.start_ns = now
            self.end_ns = now
        self._finish()

    def _finish(self) -> None:
        with self._lock:
            cbs, self._callbacks = self._callbacks, []
            self._terminal.set()
        for cb in cbs:
            cb(self)

    # -- waiting / chaining ----------------------------------------------------
    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until terminal (clWaitForEvents for one event).

        Returns False on timeout.  Raises :class:`CommandError` (with the
        original exception as ``__cause__``) if the command failed.
        """
        if not self._terminal.wait(timeout):
            return False
        if self.error is not None:
            if isinstance(self.error, CommandError):
                raise self.error
            raise CommandError(
                f"command {self.name!r} failed: {self.error}") \
                from self.error
        return True

    def add_callback(self, fn: Callable[["Event"], None]) -> None:
        """Invoke ``fn(self)`` exactly once when the event is terminal.

        Fires immediately (in the calling thread) if the event is already
        terminal; otherwise fires in the thread that completes the event —
        the clSetEventCallback contract the DAG scheduler builds on.
        """
        with self._lock:
            if not self._terminal.is_set():
                self._callbacks.append(fn)
                return
        fn(self)

    # -- profiling -------------------------------------------------------------
    @property
    def profile(self) -> Dict[str, Optional[int]]:
        """The four profiling counters, in nanoseconds (monotonic clock)."""
        return {"queued_ns": self.queued_ns, "submit_ns": self.submit_ns,
                "start_ns": self.start_ns, "end_ns": self.end_ns}

    @property
    def duration_us(self) -> Optional[float]:
        """RUNNING->terminal wall time in microseconds (None if not done)."""
        if self.end_ns is None or self.start_ns is None:
            return None
        return (self.end_ns - self.start_ns) / 1e3

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        st = "ERROR" if self.failed else self._status.name
        return f"<Event #{self.id} {self.name!r} {st}>"


class UserEvent(Event):
    """clCreateUserEvent analogue: a host-controlled gate in the DAG.

    Created in the SUBMITTED state (as in OpenCL); commands whose wait
    lists include it stay queued until the host calls :meth:`complete`
    (or :meth:`fail`, which propagates to dependents).
    """

    def __init__(self, name: str = "user"):
        super().__init__(name, queue=None, kind="user")
        self._status = EventStatus.SUBMITTED
        self.submit_ns = time.monotonic_ns()


def chunk_counters(events, kind: Optional[str] = None
                   ) -> List[Dict[str, object]]:
    """Per-event profiling rows for a set of chunk events.

    Returns one dict per *terminal* event (optionally filtered by
    ``kind``): ``name``, ``kind``, ``ok``, the four
    ``clGetEventProfilingInfo`` counters, plus two derived fields —
    ``duration_s`` (RUNNING -> terminal) and ``queue_s``
    (QUEUED -> RUNNING, the scheduling delay).  Events still in flight
    are skipped, so the rows are safe to take mid-launch.

    This is the extraction layer between raw event profiles and
    consumers that reason about chunk timing: the co-execution
    throughput model (:class:`~repro.runtime.scheduler.ThroughputModel`)
    feeds on ``duration_s`` of completed ``"kernel"`` chunks, and the
    stats tests cross-check :class:`~repro.runtime.scheduler.CoExecStats`
    against these rows."""
    rows: List[Dict[str, object]] = []
    for ev in events:
        if kind is not None and ev.kind != kind:
            continue
        if not ev.done:
            continue
        duration_s = None
        if ev.start_ns is not None and ev.end_ns is not None:
            duration_s = (ev.end_ns - ev.start_ns) / 1e9
        queue_s = None
        if ev.queued_ns is not None and ev.start_ns is not None:
            queue_s = (ev.start_ns - ev.queued_ns) / 1e9
        row: Dict[str, object] = {"name": ev.name, "kind": ev.kind,
                                  "ok": ev.succeeded}
        row.update(ev.profile)
        row["duration_s"] = duration_s
        row["queue_s"] = queue_s
        rows.append(row)
    return rows


def wait_for_events(events, timeout: Optional[float] = None) -> bool:
    """clWaitForEvents: block until every event is terminal.

    Returns False if the timeout expires first; raises if any event
    failed (after all waits resolve or time out).
    """
    deadline = None if timeout is None else time.monotonic() + timeout
    for ev in events:
        budget = None if deadline is None \
            else max(0.0, deadline - time.monotonic())
        if not ev._terminal.wait(budget):
            return False
    for ev in events:
        ev.wait(0)  # raises on failure
    return True
