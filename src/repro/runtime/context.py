"""First-class Context host object (docs/host_api.md, OpenCL §4.4).

A :class:`Context` is the root of the host object model: it owns a set
of :class:`~repro.runtime.platform.Device`\\ s, the **shared**
compilation/plan cache tier every program created in it specializes
through, a :class:`~repro.runtime.memory.BufferPool`-backed allocator
per device, and the typed :class:`~repro.core.errors.ReproError` status
hierarchy its operations raise.  The flow mirrors OpenCL end to end::

    ctx  = Context()                                   # clCreateContext
    prog = ctx.create_program(build_fn).build()        # clBuildProgram
    k    = prog.create_kernel("scale")                 # clCreateKernel
    buf  = ctx.create_buffer(1024, "float32")          # clCreateBuffer
    k.set_args(x=buf, s=2.0)                           # clSetKernelArg
    q    = ctx.create_queue(out_of_order=True)         # clCreateCommandQueue
    q.enqueue_nd_range(k, (1024,), (64,))              # clEnqueueNDRangeKernel
    q.finish()                                         # clFinish

The same ``Kernel`` object also drives multi-device co-execution
(``ctx.create_co_executor(...).launch(k, ...)``) and direct host-array
launches (:meth:`Context.launch`), with bitwise-identical results —
one compiled artifact, three dispatch paths (tests/test_host_api.py).

Because the context's cache is passed as the *plan* tier to every
specialization, heterogeneous devices compiling the same kernel share
one region-formation run (docs/caching.md §Stage-level plan caching) —
previously each device's private cache rebuilt the plan.
"""

from __future__ import annotations

import contextlib
import threading
import weakref
from typing import Callable, Dict, Iterator, List, Optional, Sequence

import numpy as np

from ..core.cache import CompilationCache
from ..core.errors import (BuildError, InvalidArgError, InvalidBufferError,
                           MapError, ReproError, status_name)
from ..core.ir import Function
from ..core.program import Kernel, Program
from .memory import BufferPool
from .platform import (Buffer, Device, Platform, create_buffer,
                       default_platform)
from .queue import CommandQueue
from .scheduler import CoExecutor
from .trace import ChromeTrace

__all__ = [
    "Context", "default_context",
    # the status hierarchy a context's operations raise, re-exported so
    # host code can catch without reaching into repro.core
    "ReproError", "InvalidArgError", "InvalidBufferError", "BuildError",
    "MapError", "status_name",
]


class Context:
    """cl_context analogue: devices + shared caches + pooled allocation.

    Parameters
    ----------
    devices:
        The devices this context spans (clCreateContext device list).
        Defaults to every device of ``platform``.
    platform:
        Defaults to the process platform
        (:func:`~repro.runtime.platform.default_platform`).
    pool_min_class:
        Smallest size class of the per-device buffer pools
        (:class:`~repro.runtime.memory.BufferPool`).
    """

    def __init__(self, devices: Optional[Sequence[Device]] = None,
                 platform: Optional[Platform] = None,
                 pool_min_class: int = 256):
        self.platform = platform or default_platform()
        # an explicit device list is a fixed scope (OpenCL semantics:
        # using another device is CL_INVALID_DEVICE); a platform-spanning
        # context adopts devices the platform grows later (co_devices)
        self._explicit_devices = devices is not None
        self.devices: List[Device] = (list(devices)
                                      if devices is not None
                                      else self.platform.get_devices())
        if not self.devices:
            raise InvalidArgError("Context needs at least one device")
        # the shared compile/plan tier: programs created in this context
        # run the target-independent middle-end through this cache, so
        # all devices (and the autotuner's multi-target sweeps) reuse
        # one WorkGroupPlan per kernel
        self.cache = CompilationCache.from_env()
        self.pool_min_class = pool_min_class
        # one pool per (device, size class floor): a caller asking for a
        # specific min_class (the serving engine's KV blocks) gets its
        # own free lists and stats instead of silently inheriting — or
        # inflating — the general-purpose pool's class floor
        self._pools: Dict[tuple, BufferPool] = {}
        # queues are tracked weakly: release() drains the live ones, but
        # the context (often the immortal default_context) must never
        # pin a dropped queue's worker threads against GC
        self._queues: "weakref.WeakSet[CommandQueue]" = weakref.WeakSet()
        # active ChromeTrace while inside a `with ctx.trace()` window:
        # queues created during the window attach themselves on creation
        self._trace: Optional[ChromeTrace] = None
        self._lock = threading.Lock()

    # -- device handling ---------------------------------------------------------
    def _check_device(self, device: Optional[Device], what: str) -> Device:
        if device is None:
            return self.devices[0]
        with self._lock:
            if device in self.devices:
                return device
            if not self._explicit_devices and \
                    device in self.platform.devices:
                # platform-spanning context: adopt devices the platform
                # grew after context creation (e.g. co_devices)
                self.devices.append(device)
                return device
        raise InvalidArgError(
            f"{what}: device {device.info.name!r} is not part of "
            f"this context (CL_INVALID_DEVICE); context devices: "
            f"{[d.info.name for d in self.devices]}")

    # -- programs / kernels -------------------------------------------------------
    def create_program(self, *builders: Callable[[], Function],
                       **options) -> Program:
        """clCreateProgramWithSource: a :class:`Program` over one or
        more IR builders, sharing this context's plan tier.  ``options``
        are the build options (``horizontal``, ``merge_uniform``,
        ``use_vml``)."""
        return Program(builders, context=self, **options)

    # -- buffers ------------------------------------------------------------------
    def pool_for(self, device: Optional[Device] = None,
                 min_class: Optional[int] = None) -> BufferPool:
        """The context's size-class pool over ``device``'s arena for the
        given ``min_class`` floor (default: the context's).  Pools are
        created lazily, one per (device, min_class) — callers with a
        dedicated class floor (the serving engine's KV blocks) get their
        own free lists and hit/miss counters, all over the same device
        arena."""
        device = self._check_device(device, "pool_for")
        mc = min_class or self.pool_min_class
        with self._lock:
            pool = self._pools.get((device, mc))
            if pool is None:
                pool = BufferPool(device.allocator, min_class=mc)
                self._pools[(device, mc)] = pool
            return pool

    def create_buffer(self, n_elems: int, dtype: str = "float32",
                      device: Optional[Device] = None,
                      pooled: bool = True) -> Buffer:
        """clCreateBuffer with typed validation: rejects zero/negative
        element counts and unknown dtypes with
        :class:`~repro.core.errors.InvalidBufferError` before the arena
        is touched.  ``pooled=True`` (default) serves the chunk from the
        context's per-device size-class pool, so steady-state
        alloc/release cycles are O(1) free-list operations — and the
        allocation is *lazy*: the chunk and payload materialize on first
        real use, so an intermediate elided by the queue's fusion
        rewrite (docs/runtime.md §Kernel fusion) never allocates."""
        device = self._check_device(device, "create_buffer")
        return create_buffer(device, n_elems, dtype,
                             pool=self.pool_for(device) if pooled
                             else None,
                             lazy=pooled)

    # -- queues / co-execution ----------------------------------------------------
    def create_queue(self, device: Optional[Device] = None,
                     out_of_order: bool = False,
                     workers: int = 2,
                     fusion: str = "flush") -> CommandQueue:
        """clCreateCommandQueue on a context device.  ``fusion`` sets the
        queue's DAG-fusion mode (``"off"`` | ``"flush"`` | ``"eager"``,
        docs/runtime.md §Kernel fusion)."""
        device = self._check_device(device, "create_queue")
        q = CommandQueue(device, out_of_order=out_of_order,
                         workers=workers, fusion=fusion)
        with self._lock:
            self._queues.add(q)
            tr = self._trace
        if tr is not None:
            tr.attach_queue(q)
        return q

    @contextlib.contextmanager
    def trace(self, tr: Optional[ChromeTrace] = None) \
            -> Iterator[ChromeTrace]:
        """Record every command on this context's queues as a Chrome
        trace (docs/mesh.md §Observability)::

            with ctx.trace() as tr:
                q.enqueue_nd_range(k, (1024,), (64,))
                q.finish()
            tr.export("out.json")       # load in chrome://tracing

        Existing queues and queues created inside the window are both
        attached; on exit collection stops but the recorded events stay
        on ``tr`` for export.  Pass a :class:`ChromeTrace` to accumulate
        several windows into one file."""
        tr = tr or ChromeTrace()
        with self._lock:
            self._trace = tr
            queues = list(self._queues)
        for q in queues:
            tr.attach_queue(q)
        try:
            yield tr
        finally:
            with self._lock:
                self._trace = None
            tr.detach_all()

    def create_co_executor(self, devices: Optional[Sequence[Device]] = None,
                           chunks_per_device: int = 4,
                           tuning_table=None,
                           min_chunk_groups: int = 1,
                           hguided_divisor: float = 2.0,
                           ewma_alpha: float = 0.5) -> CoExecutor:
        """A multi-device :class:`~repro.runtime.scheduler.CoExecutor`
        over ``devices`` (default: every context device; given devices
        are scope-checked like every other context factory) — any number
        of heterogeneous devices, each specializing kernels through the
        context's shared plan tier so N devices build a plan once.  Its
        :meth:`~repro.runtime.scheduler.CoExecutor.launch` consumes the
        same :class:`~repro.core.program.Kernel` objects queues do; the
        extra keyword arguments configure the ``adaptive`` scheduling
        mode (throughput-model EWMA, HGuided chunking, tuning-table
        weight persistence — docs/runtime.md §Scheduler)."""
        if devices is not None:
            devices = [self._check_device(d, "create_co_executor")
                       for d in devices]
        return CoExecutor(devices if devices is not None else self.devices,
                          chunks_per_device=chunks_per_device,
                          tuning_table=tuning_table,
                          min_chunk_groups=min_chunk_groups,
                          hguided_divisor=hguided_divisor,
                          ewma_alpha=ewma_alpha)

    # -- direct host launch -------------------------------------------------------
    def launch(self, kernel: Kernel, global_size: Sequence[int],
               local_size: Sequence[int],
               device: Optional[Device] = None,
               target: Optional[str] = None) -> Dict[str, np.ndarray]:
        """Synchronous single-device launch over *host-array* arguments.

        The convenience path for kernels whose buffer args are plain
        ndarrays (the old ``compile_kernel(build)(buffers, ...)``
        pattern): specializes through the device cache and returns the
        output arrays.  Device-resident :class:`Buffer` arguments
        belong on a queue (``create_queue().enqueue_nd_range``)."""
        device = self._check_device(device, "launch")
        buffers, scalars = kernel.launch_args(accept=("host",))
        binary = kernel.bind(device, local_size, target=target)
        out = binary(buffers, tuple(global_size), scalars)
        return {k: np.asarray(v) for k, v in out.items()}

    # -- introspection ------------------------------------------------------------
    def cache_stats(self) -> Dict[str, Dict[str, int]]:
        """Shared-tier + per-device compilation-cache counters."""
        stats = {"context": self.cache.stats.as_dict()}
        for d in self.devices:
            stats[d.info.name] = d.cache_stats()
        return stats

    def pool_stats(self) -> Dict[str, Dict[str, int]]:
        """Counters per pool, keyed ``"<device>[:<min_class>]"`` (the
        suffix appears for non-default class floors)."""
        with self._lock:
            out = {}
            for (d, mc), p in self._pools.items():
                key = d.info.name if mc == self.pool_min_class \
                    else f"{d.info.name}:{mc}"
                out[key] = p.stats()
            return out

    def release(self, timeout: Optional[float] = 30.0) -> None:
        """clReleaseContext analogue for the resources the context
        parks: drain and drop every queue created through
        :meth:`create_queue` (command failures are not re-raised here —
        read them off the events before releasing if they matter), and
        trim every pool back to its arena.  Buffers the caller still
        holds stay valid."""
        with self._lock:
            queues = list(self._queues)
            self._queues = weakref.WeakSet()
            pools = list(self._pools.values())
        for q in queues:
            try:
                q.finish(timeout=timeout)
            except Exception:
                pass  # failed/stuck commands must not block release
        for p in pools:
            p.trim()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"<Context devices="
                f"{[d.info.name for d in self.devices]}>")


# ---------------------------------------------------------------------------
# Process-default context (lazy singleton)
# ---------------------------------------------------------------------------

_default_context: Optional[Context] = None
_ctx_lock = threading.Lock()


def default_context() -> Context:
    """The process-default :class:`Context` over the default platform —
    subsystems that need *a* context (e.g. the serving engine when none
    is injected) share this one."""
    global _default_context
    with _ctx_lock:
        if _default_context is None:
            _default_context = Context()
        return _default_context
