"""OpenCL-shaped host layer: Platform / Device / Buffer (paper §3, Fig. 2).

The host layer is generic; device-specific behaviour lives behind the
device-layer interface, mirroring pocl's ``basic`` / ``pthread`` / ``ttasim``
driver split:

  ``basic``   — single JAX device, serial work-group execution (loop target)
  ``vector``  — single JAX device, vectorized work-groups (vector target)
  ``pallas``  — Pallas grid execution (interpret on CPU, Mosaic on TPU)
  ``mesh``    — work-groups distributed over a jax.Mesh axis (the
                multi-device analogue of the pthread driver's TLP)
  ``auto``    — target picked per kernel shape by the autotuner

Device queries (global memory size, max work-group size, …) are delegated to
the device layer exactly as the paper describes for ``clGetDeviceInfo``.
Every device owns a :class:`repro.core.cache.CompilationCache`, so repeated
``build_kernel`` calls for the same kernel/local-size are hash lookups;
``Device.cache_stats()`` / ``Platform.cache_stats()`` surface hit/miss/tune
counters (the clGetDeviceInfo-style introspection for the cache subsystem).
"""

from __future__ import annotations

import dataclasses
import threading
import time
import warnings
from typing import Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from ..core.api import CompiledKernel, _compile_kernel
from ..core.cache import CompilationCache
from ..core.errors import InvalidBufferError
from ..core.ir import Function
from .bufalloc import Bufalloc, Chunk


@dataclasses.dataclass
class DeviceInfo:
    name: str
    driver: str                 # basic | vector | pallas | mesh
    global_mem_size: int
    local_mem_size: int
    max_work_group_size: int
    compute_units: int
    # CL_DEVICE_MEM_BASE_ADDR_ALIGN, in *bytes* (OpenCL reports bits):
    # sub-buffer origins must be multiples of this (docs/memory.md)
    mem_base_addr_align: int = 4


class Device:
    """Device-layer object (cl_device_id analogue).

    Owns resource management for its memory (a :class:`Bufalloc` arena),
    a private compilation cache, and the target its driver kind maps to
    (``basic``→loop, ``vector``→vector, ``pallas``→pallas, ``auto``→
    autotuned).  Command queues bind to exactly one device; multi-device
    work uses one queue per device (runtime/scheduler.py)."""

    def __init__(self, info: DeviceInfo, jax_device=None):
        self.info = info
        self.jax_device = jax_device or jax.devices()[0]
        # Bufalloc manages the device buffer address space (the paper's
        # "host keeps book of all buffer allocations for a known region")
        self.allocator = Bufalloc(info.global_mem_size, greedy=True)
        self._target = {"basic": "loop", "vector": "vector",
                        "pallas": "pallas", "mesh": "vector",
                        "auto": "auto"}[info.driver]
        # per-device compilation cache (pocl: "the kernel compiler caches
        # the work-group function per kernel + local size"); the disk tier
        # activates when REPRO_KERNEL_CACHE_DIR is set
        self.compile_cache = CompilationCache.from_env()

    # -- device layer: kernel compilation -------------------------------------
    def compile(self, build: Callable[[], Function],
                local_size: Sequence[int], **opts) -> CompiledKernel:
        """Device-layer compilation: run the pocl pipeline for
        ``local_size`` on the device's target, memoized in the device
        cache.  Autotuned devices key their tuning decisions by device
        name, so co-executing heterogeneous devices measure
        independently.  This is the internal specialization primitive
        :meth:`repro.core.program.Program` builds on; host code should go
        through ``Context.create_program`` (docs/host_api.md)."""
        opts.setdefault("cache", self.compile_cache)
        opts.setdefault("device_key", self.info.name)
        opts.setdefault("target", self._target)
        return _compile_kernel(build, local_size, **opts)

    def build_kernel(self, build: Callable[[], Function],
                     local_size: Sequence[int], **opts) -> CompiledKernel:
        """Deprecated host entry point (clBuildProgram + clCreateKernel in
        one call).  Use ``Context.create_program(build)`` and specialize
        through :class:`~repro.core.program.Kernel` objects instead; this
        shim delegates to the same device-cache compilation."""
        warnings.warn(
            "Device.build_kernel() is deprecated; use Context."
            "create_program(build).create_kernel(name) and enqueue the "
            "Kernel object (docs/host_api.md)",
            DeprecationWarning, stacklevel=2)
        return self.compile(build, local_size, **opts)

    def cache_stats(self) -> Dict[str, int]:
        """Compilation-cache counters for this device (hits, misses,
        compiles, evictions, disk traffic, tune decisions)."""
        return self.compile_cache.stats.as_dict()

    def query(self, what: str):
        return getattr(self.info, what)


class ThrottledDevice(Device):
    """A device that models a slower — or intermittently busy — member
    of a lopsided platform (the benchmark and test double for N-device
    asymmetric co-execution, docs/runtime.md §Scheduler).

    Kernels compiled on a ThrottledDevice run the *real* computation
    (results stay bitwise-identical to any other device) and then charge
    simulated time: ``seconds_per_group`` for every work-group in the
    executed range, plus any one-shot delay armed with :meth:`stall`
    (another tenant briefly hogging the device).  The charged time lands
    inside the chunk command, so it shows up in the event profiling
    counters exactly like real execution time — which is what the
    co-execution throughput model measures.

    With ``window_chunks=True`` (the default) a ``group_range``
    sub-launch is executed by running the *full-range* kernel through
    the normal cached jit trace and windowing out the chunk's linearized
    element span — so timing-dependent adaptive chunk boundaries never
    force a fresh ``(lo, hi)`` jit trace (~100ms each, which would drown
    the simulated per-group cost).  The windowing is exact for kernels
    where work-group ``g`` writes exactly its own linearized element
    span — elementwise kernels, which is what the lopsided benchmark
    runs.  For kernels with scattered cross-group writes pass
    ``window_chunks=False`` to delegate ``group_range`` untouched.

    ``coexec_class`` (default ``"<driver>-throttled"``) is the
    device-class key the scheduler persists split weights under — give
    fast and slow wrappers different classes so their learned weights
    never alias.  ``sleep`` is injectable so tests can run simulated
    platforms in virtual time.
    """

    def __init__(self, info: DeviceInfo, jax_device=None,
                 seconds_per_group: float = 0.0,
                 coexec_class: Optional[str] = None,
                 sleep: Optional[Callable[[float], None]] = None,
                 window_chunks: bool = True):
        super().__init__(info, jax_device)
        self.seconds_per_group = float(seconds_per_group)
        self.coexec_class = coexec_class or f"{info.driver}-throttled"
        self._sleep = sleep if sleep is not None else time.sleep
        self.window_chunks = bool(window_chunks)
        self._stall_s = 0.0
        self._stall_lock = threading.Lock()

    def stall(self, seconds: float) -> None:
        """Arm a one-shot delay charged to the next kernel execution on
        this device."""
        with self._stall_lock:
            self._stall_s += float(seconds)

    def _consume_stall(self) -> float:
        with self._stall_lock:
            s, self._stall_s = self._stall_s, 0.0
            return s

    def compile(self, build: Callable[[], Function],
                local_size: Sequence[int], **opts) -> "_ThrottledKernel":
        inner = super().compile(build, local_size, **opts)
        return _ThrottledKernel(inner, self,
                                tuple(int(x) for x in local_size))


class _ThrottledKernel:
    """Launchable proxy that charges its ThrottledDevice's simulated
    time per executed work-group (plus any armed stall) after running
    the real kernel."""

    def __init__(self, kernel, device: ThrottledDevice,
                 local_size: Sequence[int]):
        self._kernel = kernel
        self._device = device
        self._local = tuple(local_size)

    def __getattr__(self, name):
        return getattr(self._kernel, name)

    def _window(self, buffers, global_size, scalars, jit, lo, hi):
        """Execute groups ``[lo, hi)`` by windowing the cached full-range
        launch: bitwise-identical to a real ``group_range`` sub-launch
        for kernels whose group ``g`` writes its own linearized element
        span, and free of per-span retracing."""
        full = self._kernel(buffers, global_size, scalars, jit=jit)
        L = 1
        for x in self._local:
            L *= max(1, int(x))
        out = {}
        for nm, arr in buffers.items():
            base = np.asarray(arr)
            res = base.reshape(-1).copy()
            f = np.asarray(full[nm]).reshape(-1)
            res[lo * L:hi * L] = f[lo * L:hi * L]
            out[nm] = res.reshape(base.shape)
        return out

    def __call__(self, buffers, global_size, scalars=None, jit: bool = True,
                 group_range=None):
        d = self._device
        if group_range is not None:
            lo, hi = int(group_range[0]), int(group_range[1])
            groups = max(0, hi - lo)
            if d.window_chunks:
                out = self._window(buffers, global_size, scalars, jit,
                                   lo, hi)
            else:
                out = self._kernel(buffers, global_size, scalars, jit=jit,
                                   group_range=group_range)
        else:
            out = self._kernel(buffers, global_size, scalars, jit=jit)
            gsz = tuple(global_size) + (1,) * (3 - len(global_size))
            lsz = self._local + (1,) * (3 - len(self._local))
            groups = 1
            for g, l in zip(gsz, lsz):
                groups *= max(1, g // max(1, l))
        delay = d._consume_stall() + groups * d.seconds_per_group
        if delay > 0:
            d._sleep(delay)
        return out


class Buffer:
    """A device buffer (cl_mem analogue) backed by a Bufalloc chunk plus a
    host-side array mirror (the actual payload on this simulated device).

    The hierarchical-memory subsystem (:mod:`repro.runtime.memory`,
    docs/memory.md) extends every buffer with

    * **view bookkeeping** — :attr:`origin`/:attr:`root` let sub-buffer
      views and the root share one identity for residency and mapping;
    * **residency binding** — :meth:`bind_residency` attaches a
      :class:`~repro.runtime.bufalloc.ResidencyTracker`, after which any
      write through the buffer *or any aliased view of it* invalidates
      the overlapping span of every other device's copy;
    * **map bookkeeping** — active :class:`~repro.runtime.memory.
      MappedRegion`\\ s are registered on the root so overlapping write
      maps (and kernel launches over write-mapped buffers) are rejected.
    """

    def __init__(self, device: Device, size_bytes: int, dtype: str,
                 n_elems: int, pool=None, lazy: bool = False):
        self.device = device
        # a pool-backed buffer draws its chunk from (and releases it to)
        # a size-class BufferPool over the device arena instead of the
        # raw first-fit allocator (Context.create_buffer does this)
        self._pool = pool
        self._size_bytes = size_bytes
        # a lazy buffer defers both the chunk and the payload until first
        # real use, so a fusion-elided intermediate that is only ever the
        # stitched-away link of a chain never allocates at all
        # (docs/memory.md §Lazy pooled buffers)
        self.chunk: Optional[Chunk] = None if lazy else (
            pool.alloc(size_bytes) if pool is not None
            else device.allocator.alloc(size_bytes))
        self.dtype = dtype
        self.itemsize = np.dtype(dtype).itemsize
        self.n_elems = n_elems
        self.nbytes = n_elems * self.itemsize
        self.origin = 0                       # byte offset within root
        self._data: Optional[np.ndarray] = (None if lazy
                                            else np.zeros(n_elems, dtype))
        # residency binding (None until bind_residency)
        self._tracker = None
        self._res_key = None
        self._res_dev = None
        # zero-copy map bookkeeping (root buffers only)
        self._maps: List[object] = []         # active MappedRegions
        self._map_lock = threading.Lock()
        # optional read-back hook run by READ maps before publishing the
        # view (e.g. pull the canonical copy of a shared buffer);
        # MAP_WRITE_INVALIDATE skips it — that is the skipped read-back
        self.on_map_sync: Optional[Callable[[int, int], None]] = None

    @property
    def root(self) -> "Buffer":
        """The underlying root allocation (self for non-view buffers)."""
        return self

    # -- lazy materialization ---------------------------------------------------
    @property
    def materialized(self) -> bool:
        """True once the device chunk and payload exist.  Lazy buffers
        (``Context.create_buffer(pooled=True)``) stay unmaterialized
        until the first real use; an elided fusion intermediate is
        *never* real use, so its ``bytes_elided`` are genuinely saved."""
        return self._data is not None

    def _materialize(self) -> None:
        if self._data is not None:
            return
        if self.chunk is None:
            self.chunk = (self._pool.alloc(self._size_bytes)
                          if self._pool is not None
                          else self.device.allocator.alloc(self._size_bytes))
        self._data = np.zeros(self.n_elems, self.dtype)

    @property
    def data(self) -> np.ndarray:
        """The host-side payload mirror; touching it is 'first real use'
        and materializes a lazy buffer."""
        self._materialize()
        return self._data

    @data.setter
    def data(self, arr: np.ndarray) -> None:
        if self.chunk is None:
            self.chunk = (self._pool.alloc(self._size_bytes)
                          if self._pool is not None
                          else self.device.allocator.alloc(self._size_bytes))
        self._data = arr

    # -- residency ------------------------------------------------------------
    def bind_residency(self, tracker, key, device_key) -> None:
        """Attach a ResidencyTracker: from now on every write through
        this buffer or any of its views calls ``tracker.wrote_span`` for
        exactly the written byte span, invalidating other device copies
        at sub-buffer granularity."""
        self._tracker = tracker
        self._res_key = key
        self._res_dev = device_key

    def mark_written_span(self, lo: int, hi: int) -> None:
        """Record that bytes ``[lo, hi)`` (buffer-relative) were written
        on this buffer's device."""
        if self._tracker is not None:
            self._tracker.wrote_span(self._res_key, self._res_dev,
                                     self.origin + lo, self.origin + hi)

    def mark_written(self) -> None:
        self.mark_written_span(0, self.nbytes)

    # -- map bookkeeping (queried by CommandQueue._launch) ----------------------
    @property
    def map_count(self) -> int:
        """Number of active mapped regions over the *root* allocation."""
        with self.root._map_lock:
            return len(self.root._maps)

    def release(self) -> None:
        if self.chunk is not None:
            if self._pool is not None:
                self._pool.free(self.chunk)
            else:
                self.device.allocator.free(self.chunk)
            self.chunk = None


class Platform:
    """clGetPlatformIDs analogue: enumerates devices for the process."""

    def __init__(self):
        self.devices: List[Device] = []
        ndev = len(jax.devices())
        for i, d in enumerate(jax.devices()):
            self.devices.append(Device(DeviceInfo(
                name=f"repro-{d.platform}-{i}", driver="vector",
                global_mem_size=1 << 30, local_mem_size=1 << 20,
                max_work_group_size=1024, compute_units=ndev), d))
        # a 'basic' serial device is always available (pocl's reference)
        self.devices.append(Device(DeviceInfo(
            name="repro-basic", driver="basic",
            global_mem_size=1 << 30, local_mem_size=1 << 20,
            max_work_group_size=1024, compute_units=1)))
        self.devices.append(Device(DeviceInfo(
            name="repro-pallas", driver="pallas",
            global_mem_size=1 << 30, local_mem_size=1 << 20,
            max_work_group_size=1024, compute_units=1)))
        # an autotuned device: the target is picked per kernel shape by
        # measurement (the per-platform mapping choice of Rupp & Weinbub)
        self.devices.append(Device(DeviceInfo(
            name="repro-auto", driver="auto",
            global_mem_size=1 << 30, local_mem_size=1 << 20,
            max_work_group_size=1024, compute_units=1)))

    def get_devices(self, driver: Optional[str] = None) -> List[Device]:
        """clGetDeviceIDs: all devices, or those of one driver kind."""
        if driver is None:
            return list(self.devices)
        return [d for d in self.devices if d.info.driver == driver]

    def co_devices(self, n: int, driver: str = "vector") -> List[Device]:
        """Create ``n`` fresh homogeneous devices for multi-device
        co-execution (the analogue of EngineCL's device set over one
        platform).  Each device owns its own allocator and compilation
        cache; the multi-device scheduler (runtime/scheduler.py) fans
        sub-ranges of one NDRange out across them.  The devices are
        appended to :attr:`devices` so ``cache_stats`` sees them."""
        out = []
        for i in range(n):
            d = Device(DeviceInfo(
                name=f"repro-co-{driver}-{i}", driver=driver,
                global_mem_size=1 << 30, local_mem_size=1 << 20,
                max_work_group_size=1024, compute_units=1))
            out.append(d)
        self.devices.extend(out)
        return out

    def cache_stats(self) -> Dict[str, Dict[str, int]]:
        """Per-device compilation-cache counters, keyed by device name."""
        return {d.info.name: d.cache_stats() for d in self.devices}


def validate_buffer_request(n_elems, dtype) -> int:
    """Validate a buffer-creation request; returns the element size.

    Raises :class:`~repro.core.errors.InvalidBufferError`
    (CL_INVALID_BUFFER_SIZE) for a zero/negative/non-integral element
    count or an unknown dtype string — *before* the request reaches the
    Bufalloc arena, which would otherwise fail deep inside chunk
    bookkeeping with an untyped error (or silently clamp a zero-byte
    allocation to the alignment granule)."""
    if isinstance(n_elems, bool) or not isinstance(
            n_elems, (int, np.integer)):
        raise InvalidBufferError(
            f"buffer element count must be an integer, got "
            f"{type(n_elems).__name__} ({n_elems!r})")
    if n_elems <= 0:
        raise InvalidBufferError(
            f"buffer element count must be positive, got {n_elems}")
    try:
        itemsize = np.dtype(dtype).itemsize
    except TypeError as e:
        raise InvalidBufferError(
            f"unknown buffer dtype {dtype!r}: {e}") from None
    return itemsize


def create_buffer(device: Device, n_elems: int, dtype: str = "float32",
                  pool=None, lazy: bool = False) -> Buffer:
    """clCreateBuffer: allocate ``n_elems`` of ``dtype`` on ``device``.
    ``pool`` (a :class:`~repro.runtime.memory.BufferPool` over the
    device's arena) serves the chunk from a size-class free list —
    ``Context.create_buffer`` passes the context's per-device pool.
    ``lazy=True`` defers chunk + payload to first real use (pooled
    context buffers default to this, enabling fusion elision)."""
    itemsize = validate_buffer_request(n_elems, dtype)
    return Buffer(device, int(n_elems) * itemsize, dtype, int(n_elems),
                  pool=pool, lazy=lazy)


# ---------------------------------------------------------------------------
# Process-default platform (lazy singleton)
# ---------------------------------------------------------------------------

_default_platform: Optional[Platform] = None
_platform_lock = threading.Lock()


def default_platform() -> Platform:
    """The process-default :class:`Platform` (clGetPlatformIDs returns the
    same platform object for every caller).  Subsystems that need *a*
    device for host-side command scheduling — e.g. the serving engine's
    DAG queue — share this one instead of enumerating devices per
    instance."""
    global _default_platform
    with _platform_lock:
        if _default_platform is None:
            _default_platform = Platform()
        return _default_platform
