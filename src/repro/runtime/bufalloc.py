"""Bufalloc — the pocl kernel-buffer allocator (paper §3).

Faithful reimplementation of the design described in the paper:

* a single large *region* is obtained up front (one malloc / static array /
  known device-memory range) — here it models an HBM arena;
* internal book-keeping is a list of **chunks** ordered by start address,
  each with a free/allocated flag and a size;
* the **last chunk is a sentinel** holding all unallocated memory;
* allocation walks the list **first-fit** and splits the found chunk in two:
  one with the exact request size (returned) and one with the remainder;
* an optional **greedy mode** always serves new requests from the sentinel
  (end of region) when possible, so successive allocations of a kernel's
  buffer group land in continuous memory;
* frees coalesce with free neighbours — the workload assumption is
  long-lived buffers allocated and freed in groups, so fragmentation stays
  low by construction.

The serving engine uses a Bufalloc arena for its paged KV cache
(:mod:`repro.serve.kvcache`), and the OpenCL-style runtime uses it for
``clCreateBuffer`` book-keeping on devices without their own allocator.

:class:`ResidencyTracker` extends the same host-side book-keeping across
*devices*: it records which devices currently hold a valid copy of each
shared buffer — and, at **byte-span granularity**, which parts of each
copy are stale — so the multi-device co-execution scheduler
(:mod:`repro.runtime.scheduler`) migrates a buffer to a device **once** —
not once per sub-range launch — re-migrates only the spans another device
wrote, and invalidates exactly the span a write through any aliased
sub-buffer view or mapped region touched (the implicit cl_mem migration
of OpenCL §5.3, "moved to the device on first use, cached until another
device writes", refined to sub-buffer granularity; docs/memory.md).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Dict, Hashable, Iterator, List, Optional, Tuple

from ..core.errors import ReproError, register_error


@register_error
class OutOfMemory(ReproError, MemoryError):
    """Arena exhausted (CL_MEM_OBJECT_ALLOCATION_FAILURE).  Part of the
    typed :class:`~repro.core.errors.ReproError` hierarchy."""

    code = -4
    code_name = "CL_MEM_OBJECT_ALLOCATION_FAILURE"


@dataclass
class Chunk:
    start: int
    size: int
    free: bool
    prev: Optional["Chunk"] = None
    next: Optional["Chunk"] = None

    def __repr__(self) -> str:  # pragma: no cover
        return f"<{'F' if self.free else 'A'} @{self.start} +{self.size}>"


class Bufalloc:
    def __init__(self, region_size: int, alignment: int = 64,
                 greedy: bool = False):
        assert region_size > 0 and alignment > 0
        self.region_size = region_size
        self.alignment = alignment
        self.greedy = greedy
        # sentinel last chunk holds all unallocated memory
        self._head = Chunk(0, region_size, True)
        self._sentinel = self._head
        self._allocated = 0
        self.n_allocs = 0
        self.n_frees = 0

    # -- helpers ---------------------------------------------------------------
    def _align(self, n: int) -> int:
        a = self.alignment
        return (n + a - 1) // a * a

    def chunks(self) -> Iterator[Chunk]:
        c = self._head
        while c is not None:
            yield c
            c = c.next

    # -- allocation --------------------------------------------------------------
    def alloc(self, size: int) -> Chunk:
        """First-fit allocation; greedy mode serves from the sentinel."""
        req = self._align(max(size, 1))
        target: Optional[Chunk] = None
        if self.greedy and self._sentinel.free and self._sentinel.size >= req:
            target = self._sentinel
        else:
            for c in self.chunks():
                if c.free and c.size >= req:
                    target = c
                    break
        if target is None:
            raise OutOfMemory(
                f"Bufalloc: {size} bytes requested, "
                f"{self.free_bytes()} free (fragmented into "
                f"{sum(1 for c in self.chunks() if c.free)} chunks)")
        # split: exact-size allocated chunk + remainder chunk
        if target.size > req:
            rest = Chunk(target.start + req, target.size - req, True,
                         prev=target, next=target.next)
            if target.next is not None:
                target.next.prev = rest
            target.next = rest
            target.size = req
            if target is self._sentinel:
                self._sentinel = rest
        elif target is self._sentinel:
            # sentinel fully consumed; new sentinel is the last free chunk
            self._sentinel = target
        target.free = False
        self._allocated += req
        self.n_allocs += 1
        return target

    def free(self, chunk: Chunk) -> None:
        assert not chunk.free, "double free"
        chunk.free = True
        self._allocated -= chunk.size
        self.n_frees += 1
        # coalesce with free neighbours
        if chunk.next is not None and chunk.next.free:
            nxt = chunk.next
            chunk.size += nxt.size
            chunk.next = nxt.next
            if nxt.next is not None:
                nxt.next.prev = chunk
            if nxt is self._sentinel:
                self._sentinel = chunk
        if chunk.prev is not None and chunk.prev.free:
            prv = chunk.prev
            prv.size += chunk.size
            prv.next = chunk.next
            if chunk.next is not None:
                chunk.next.prev = prv
            if chunk is self._sentinel:
                self._sentinel = prv

    def alloc_group(self, sizes: List[int]) -> List[Chunk]:
        """Allocate a kernel's buffer group with successive calls (the
        paper's usage pattern); greedy mode makes these contiguous."""
        out: List[Chunk] = []
        try:
            for s in sizes:
                out.append(self.alloc(s))
        except OutOfMemory:
            for c in out:
                self.free(c)
            raise
        return out

    def free_group(self, chunks: List[Chunk]) -> None:
        for c in chunks:
            self.free(c)

    # -- introspection -------------------------------------------------------------
    def free_bytes(self) -> int:
        return self.region_size - self._allocated

    def allocated_bytes(self) -> int:
        return self._allocated

    def largest_free(self) -> int:
        return max((c.size for c in self.chunks() if c.free), default=0)

    def fragmentation(self) -> float:
        """1 - largest_free/free_bytes (0 = unfragmented)."""
        fb = self.free_bytes()
        if fb == 0:
            return 0.0
        return 1.0 - self.largest_free() / fb

    def check_invariants(self) -> None:
        prev_end = 0
        prev = None
        last = None
        for c in self.chunks():
            assert c.start == prev_end, "chunks must be contiguous"
            assert c.size > 0
            assert c.prev is prev
            prev_end = c.start + c.size
            prev = c
            last = c
        assert prev_end == self.region_size
        # the sentinel is always the last chunk of the region (it starts
        # as the whole-region free chunk and every alloc/free path that
        # splits or merges the tail re-points it there)
        assert last is self._sentinel, "sentinel must be the last chunk"
        # no two adjacent free chunks (coalescing invariant)
        for c in self.chunks():
            if c.free and c.next is not None:
                assert not c.next.free, "adjacent free chunks not coalesced"


# ---------------------------------------------------------------------------
# Byte-span interval arithmetic for span-granular residency
# ---------------------------------------------------------------------------

#: open upper bound for "stale to the end of the buffer" — clipped to the
#: real buffer size whenever a caller provides one (acquire_spans)
SPAN_END = 1 << 62

Span = Tuple[int, int]


def span_union(spans: List[Span], lo: int, hi: int) -> List[Span]:
    """Insert ``[lo, hi)`` into a sorted disjoint span list, merging."""
    if hi <= lo:
        return list(spans)
    out: List[Span] = []
    placed = False
    for s, e in spans:
        if e < lo or (placed and s > hi):
            out.append((s, e))
        elif s > hi:
            if not placed:
                out.append((lo, hi))
                placed = True
            out.append((s, e))
        else:  # overlaps or touches [lo, hi): absorb
            lo, hi = min(lo, s), max(hi, e)
    if not placed:
        out.append((lo, hi))
    out.sort()
    return out


def span_subtract(spans: List[Span], lo: int, hi: int) -> List[Span]:
    """Remove ``[lo, hi)`` from a sorted disjoint span list."""
    if hi <= lo:
        return list(spans)
    out: List[Span] = []
    for s, e in spans:
        if e <= lo or s >= hi:
            out.append((s, e))
            continue
        if s < lo:
            out.append((s, lo))
        if e > hi:
            out.append((hi, e))
    return out


def span_clip(spans: List[Span], size: int) -> List[Span]:
    """Clip a span list to ``[0, size)`` (drops empty leftovers)."""
    return [(s, min(e, size)) for s, e in spans if s < size]


def span_total(spans: List[Span]) -> int:
    return sum(e - s for s, e in spans)


class ResidencyTracker:
    """Which devices hold a valid copy of each shared buffer — and, since
    the hierarchical-memory subsystem (docs/memory.md), *which byte spans*
    of each copy are stale.

    Keys are opaque hashables (the scheduler uses buffer identities);
    devices likewise.  The contract mirrors OpenCL's implicit cl_mem
    migration, refined to sub-buffer granularity:

    * :meth:`acquire` — a device is about to *read* the whole buffer.
      Returns True when a copy is due (no copy at all, or any stale
      span), False on a residency hit.  Binary compatibility shim over
      :meth:`acquire_spans`.
    * :meth:`acquire_spans` — the span-granular read: returns exactly the
      byte spans the caller must copy to make the device copy current
      (``[]`` = hit, ``[(0, size)]`` = full migration, anything else =
      **partial migration** — e.g. re-reading after another device wrote
      a disjoint sub-range).
    * :meth:`wrote` — a launch *wrote* the whole buffer on a device/host;
      every other copy becomes fully stale.
    * :meth:`wrote_span` — a write through an aliased view (sub-buffer,
      mapped region, ``group_range`` sub-launch): the writing device's
      copy becomes valid over ``[lo, hi)`` and every *other* copy becomes
      stale over exactly that span — not the whole buffer.
    * :meth:`validate` — mark a device's copy fully current without
      invalidating anyone (used for the canonical host copy after a
      merge already accounted for per-device writes).
    * :meth:`drop` — forget a buffer entirely (released).

    Thread-safe: sub-range launches acquire concurrently from the
    per-device queue workers.
    """

    def __init__(self) -> None:
        # per key: device -> sorted disjoint list of *stale* byte spans
        # (device present = holds a copy; empty list = fully valid)
        self._copies: Dict[Hashable, Dict[Hashable, List[Span]]] = {}
        self._lock = threading.Lock()
        self.migrations = 0         # copy operations that happened
        self.partial_migrations = 0  # ...of which only stale spans moved
        self.hits = 0               # reads served by a valid copy
        self.bytes_migrated = 0     # bytes actually copied (span API only)

    # -- reads ----------------------------------------------------------------
    def acquire(self, key: Hashable, device: Hashable) -> bool:
        """Record a whole-buffer read of ``key`` on ``device``; True if a
        copy is due (the caller copies the full buffer)."""
        with self._lock:
            copies = self._copies.setdefault(key, {})
            stale = copies.get(device)
            if stale is not None and not stale:
                self.hits += 1
                return False
            copies[device] = []
            self.migrations += 1
            return True

    def acquire_spans(self, key: Hashable, device: Hashable,
                      size: int) -> List[Span]:
        """Span-granular read of a ``size``-byte buffer on ``device``.

        Returns the byte spans the caller must copy from the canonical
        data; the device copy is considered fully valid afterwards."""
        with self._lock:
            copies = self._copies.setdefault(key, {})
            stale = copies.get(device)
            if stale is None:
                copies[device] = []
                self.migrations += 1
                self.bytes_migrated += size
                return [(0, size)]
            due = span_clip(stale, size)
            copies[device] = []
            if not due:
                self.hits += 1
                return []
            self.migrations += 1
            if span_total(due) < size:
                self.partial_migrations += 1
            self.bytes_migrated += span_total(due)
            return due

    # -- writes ---------------------------------------------------------------
    def wrote(self, key: Hashable, device: Hashable) -> None:
        """Record a whole-buffer write on ``device``: it becomes the sole
        valid copy."""
        with self._lock:
            self._copies[key] = {device: []}

    def wrote_span(self, key: Hashable, device: Hashable,
                   lo: int, hi: int) -> None:
        """Record a write of bytes ``[lo, hi)`` on ``device``.

        The writing copy becomes valid over the span; every other copy
        becomes stale over the span *only* — the write-invalidation
        granularity sub-buffers and ``group_range`` sub-launches need."""
        if hi <= lo:
            return
        with self._lock:
            copies = self._copies.setdefault(key, {})
            for dev in list(copies):
                if dev == device:
                    copies[dev] = span_subtract(copies[dev], lo, hi)
                else:
                    copies[dev] = span_union(copies[dev], lo, hi)
            if device not in copies:
                # writer had no copy: valid exactly over what it wrote
                copies[device] = [(s, e) for s, e in
                                  ((0, lo), (hi, SPAN_END)) if e > s]

    def validate(self, key: Hashable, device: Hashable) -> None:
        """Mark ``device``'s copy fully current without staling others."""
        with self._lock:
            self._copies.setdefault(key, {})[device] = []

    # -- introspection ---------------------------------------------------------
    def resident(self, key: Hashable, device: Hashable,
                 size: Optional[int] = None) -> bool:
        """True when ``device`` holds a fully valid copy of ``key``.

        Pass ``size`` to ignore bookkeeping staleness beyond the real
        buffer end (a writer that never held a full copy is marked stale
        to ``SPAN_END`` because the tracker does not know buffer sizes)."""
        with self._lock:
            stale = self._copies.get(key, {}).get(device)
            if stale is None:
                return False
            if size is not None:
                stale = span_clip(stale, size)
            return not stale

    def stale_spans(self, key: Hashable, device: Hashable,
                    size: Optional[int] = None) -> Optional[List[Span]]:
        """The device copy's stale spans (``None`` = no copy at all)."""
        with self._lock:
            stale = self._copies.get(key, {}).get(device)
            if stale is None:
                return None
            return span_clip(stale, size) if size is not None \
                else list(stale)

    def drop(self, key: Hashable) -> None:
        with self._lock:
            self._copies.pop(key, None)

    def stats(self) -> Dict[str, int]:
        """Migration/hit counters plus the number of tracked buffers."""
        with self._lock:
            return {"migrations": self.migrations,
                    "partial_migrations": self.partial_migrations,
                    "hits": self.hits,
                    "bytes_migrated": self.bytes_migrated,
                    "tracked": len(self._copies)}
