"""Bufalloc — the pocl kernel-buffer allocator (paper §3).

Faithful reimplementation of the design described in the paper:

* a single large *region* is obtained up front (one malloc / static array /
  known device-memory range) — here it models an HBM arena;
* internal book-keeping is a list of **chunks** ordered by start address,
  each with a free/allocated flag and a size;
* the **last chunk is a sentinel** holding all unallocated memory;
* allocation walks the list **first-fit** and splits the found chunk in two:
  one with the exact request size (returned) and one with the remainder;
* an optional **greedy mode** always serves new requests from the sentinel
  (end of region) when possible, so successive allocations of a kernel's
  buffer group land in continuous memory;
* frees coalesce with free neighbours — the workload assumption is
  long-lived buffers allocated and freed in groups, so fragmentation stays
  low by construction.

The serving engine uses a Bufalloc arena for its paged KV cache
(:mod:`repro.serve.kvcache`), and the OpenCL-style runtime uses it for
``clCreateBuffer`` book-keeping on devices without their own allocator.

:class:`ResidencyTracker` extends the same host-side book-keeping across
*devices*: it records which devices currently hold a valid copy of each
shared buffer, so the multi-device co-execution scheduler
(:mod:`repro.runtime.scheduler`) migrates a buffer to a device **once** —
not once per sub-range launch — and invalidates stale copies when a launch
writes it (the implicit cl_mem migration of OpenCL §5.3: "moved to the
device on first use, cached until another device writes").
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Dict, Hashable, Iterator, List, Optional, Set


class OutOfMemory(Exception):
    pass


@dataclass
class Chunk:
    start: int
    size: int
    free: bool
    prev: Optional["Chunk"] = None
    next: Optional["Chunk"] = None

    def __repr__(self) -> str:  # pragma: no cover
        return f"<{'F' if self.free else 'A'} @{self.start} +{self.size}>"


class Bufalloc:
    def __init__(self, region_size: int, alignment: int = 64,
                 greedy: bool = False):
        assert region_size > 0 and alignment > 0
        self.region_size = region_size
        self.alignment = alignment
        self.greedy = greedy
        # sentinel last chunk holds all unallocated memory
        self._head = Chunk(0, region_size, True)
        self._sentinel = self._head
        self._allocated = 0
        self.n_allocs = 0
        self.n_frees = 0

    # -- helpers ---------------------------------------------------------------
    def _align(self, n: int) -> int:
        a = self.alignment
        return (n + a - 1) // a * a

    def chunks(self) -> Iterator[Chunk]:
        c = self._head
        while c is not None:
            yield c
            c = c.next

    # -- allocation --------------------------------------------------------------
    def alloc(self, size: int) -> Chunk:
        """First-fit allocation; greedy mode serves from the sentinel."""
        req = self._align(max(size, 1))
        target: Optional[Chunk] = None
        if self.greedy and self._sentinel.free and self._sentinel.size >= req:
            target = self._sentinel
        else:
            for c in self.chunks():
                if c.free and c.size >= req:
                    target = c
                    break
        if target is None:
            raise OutOfMemory(
                f"Bufalloc: {size} bytes requested, "
                f"{self.free_bytes()} free (fragmented into "
                f"{sum(1 for c in self.chunks() if c.free)} chunks)")
        # split: exact-size allocated chunk + remainder chunk
        if target.size > req:
            rest = Chunk(target.start + req, target.size - req, True,
                         prev=target, next=target.next)
            if target.next is not None:
                target.next.prev = rest
            target.next = rest
            target.size = req
            if target is self._sentinel:
                self._sentinel = rest
        elif target is self._sentinel:
            # sentinel fully consumed; new sentinel is the last free chunk
            self._sentinel = target
        target.free = False
        self._allocated += req
        self.n_allocs += 1
        return target

    def free(self, chunk: Chunk) -> None:
        assert not chunk.free, "double free"
        chunk.free = True
        self._allocated -= chunk.size
        self.n_frees += 1
        # coalesce with free neighbours
        if chunk.next is not None and chunk.next.free:
            nxt = chunk.next
            chunk.size += nxt.size
            chunk.next = nxt.next
            if nxt.next is not None:
                nxt.next.prev = chunk
            if nxt is self._sentinel:
                self._sentinel = chunk
        if chunk.prev is not None and chunk.prev.free:
            prv = chunk.prev
            prv.size += chunk.size
            prv.next = chunk.next
            if chunk.next is not None:
                chunk.next.prev = prv
            if chunk is self._sentinel:
                self._sentinel = prv

    def alloc_group(self, sizes: List[int]) -> List[Chunk]:
        """Allocate a kernel's buffer group with successive calls (the
        paper's usage pattern); greedy mode makes these contiguous."""
        out: List[Chunk] = []
        try:
            for s in sizes:
                out.append(self.alloc(s))
        except OutOfMemory:
            for c in out:
                self.free(c)
            raise
        return out

    def free_group(self, chunks: List[Chunk]) -> None:
        for c in chunks:
            self.free(c)

    # -- introspection -------------------------------------------------------------
    def free_bytes(self) -> int:
        return self.region_size - self._allocated

    def allocated_bytes(self) -> int:
        return self._allocated

    def largest_free(self) -> int:
        return max((c.size for c in self.chunks() if c.free), default=0)

    def fragmentation(self) -> float:
        """1 - largest_free/free_bytes (0 = unfragmented)."""
        fb = self.free_bytes()
        if fb == 0:
            return 0.0
        return 1.0 - self.largest_free() / fb

    def check_invariants(self) -> None:
        prev_end = 0
        prev = None
        for c in self.chunks():
            assert c.start == prev_end, "chunks must be contiguous"
            assert c.size > 0
            assert c.prev is prev
            prev_end = c.start + c.size
            prev = c
        assert prev_end == self.region_size
        # no two adjacent free chunks (coalescing invariant)
        for c in self.chunks():
            if c.free and c.next is not None:
                assert not c.next.free, "adjacent free chunks not coalesced"


class ResidencyTracker:
    """Which devices hold a valid copy of each shared buffer.

    Keys are opaque hashables (the scheduler uses buffer identities);
    devices likewise.  The contract mirrors OpenCL's implicit cl_mem
    migration:

    * :meth:`acquire` — a device is about to *read* the buffer.  Returns
      True when the device has no valid copy (the caller must copy the
      canonical data over; counted as a **migration**), False on a
      residency hit (no copy needed — this is what makes a buffer touched
      on two devices copy once, not once per launch).
    * :meth:`wrote` — a launch *wrote* the buffer on (or back to) a
      device/host; every other copy becomes stale.
    * :meth:`drop` — forget a buffer entirely (released).

    Thread-safe: sub-range launches acquire concurrently from the
    per-device queue workers.
    """

    def __init__(self) -> None:
        self._valid: Dict[Hashable, Set[Hashable]] = {}
        self._lock = threading.Lock()
        self.migrations = 0       # copies that actually happened
        self.hits = 0             # reads served by an existing valid copy

    def acquire(self, key: Hashable, device: Hashable) -> bool:
        """Record a read of ``key`` on ``device``; True if a copy is due."""
        with self._lock:
            holders = self._valid.setdefault(key, set())
            if device in holders:
                self.hits += 1
                return False
            holders.add(device)
            self.migrations += 1
            return True

    def wrote(self, key: Hashable, device: Hashable) -> None:
        """Record a write on ``device``: it becomes the sole valid copy."""
        with self._lock:
            self._valid[key] = {device}

    def resident(self, key: Hashable, device: Hashable) -> bool:
        with self._lock:
            return device in self._valid.get(key, ())

    def drop(self, key: Hashable) -> None:
        with self._lock:
            self._valid.pop(key, None)

    def stats(self) -> Dict[str, int]:
        """Migration/hit counters plus the number of tracked buffers."""
        with self._lock:
            return {"migrations": self.migrations, "hits": self.hits,
                    "tracked": len(self._valid)}
