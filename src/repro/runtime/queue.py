"""Command queues over an explicit event dependency DAG (paper §2/§3).

Commands (kernel launches, buffer reads/writes, native host functions) are
enqueued with optional ``wait_for`` event lists and return an
:class:`~repro.runtime.events.Event`.  In-order queues add an implicit
dependency on the previously enqueued command; out-of-order queues execute
any command whose dependencies are resolved — the paper's observation that
commands in an out-of-order queue "can be assumed to be independent of each
other unless explicitly synchronized using events".

Scheduling is **push-based**: ``flush()`` submits every flushed command
whose wait list is already resolved, and each event completion decrements
its dependents' outstanding-dependency counters, submitting newly-ready
commands from the completing thread — no polling loop.  The worker pool
plays the role of pocl's pthread-driver launcher threads; cross-queue and
cross-device dependencies work because the resolution mechanism is the
event itself, not queue-local state.

Every event moves QUEUED -> SUBMITTED -> RUNNING -> COMPLETE with
nanosecond profiling timestamps (docs/runtime.md maps each call here to
its OpenCL counterpart).  A failing command terminates its event with the
error and every transitive dependent fails with ``DependencyError``
without running.

``enqueue_kernel`` is the pocl-faithful enqueue path: the work-group
function is specialized at enqueue time (paper §4.1) through the device's
compilation cache — the first enqueue compiles, every later enqueue of the
same kernel/local-size is a hash lookup.  ``self.stats`` counts launches
and enqueue-time compiles for the dispatch-overhead story.

``enqueue_map_buffer``/``enqueue_unmap_buffer`` put zero-copy host access
on the same DAG (docs/memory.md): the map event's completion publishes an
ndarray view into the buffer payload, the unmap publishes write spans to
the residency tracker, and launches (or device-side writes) over an
allocation with *any* active map are rejected — the write-back would
race with or silently detach the zero-copy host view.  Kernel launches
accept sub-buffer views anywhere a buffer is accepted, with in-place
write-back into the parent's span.
"""

from __future__ import annotations

import threading
import warnings
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.api import CompiledKernel
from ..core.program import Kernel
from .events import (CommandError, DependencyError, Event, EventStatus,
                     UserEvent, wait_for_events)
from .memory import (MAP_READ_WRITE, MAP_WRITE_INVALIDATE, MapError,
                     MappedRegion, _flat_view)
from .platform import Buffer, Device


class _Command:
    """One node of the DAG: a host thunk plus its event and wait list."""

    __slots__ = ("fn", "event", "deps", "remaining", "submitted",
                 "failed_dep")

    def __init__(self, fn: Callable[[], None], event: Event,
                 deps: Sequence[Event]):
        self.fn = fn
        self.event = event
        self.deps: List[Event] = list(deps)
        self.remaining = 0            # unresolved deps (set when armed)
        self.submitted = False
        self.failed_dep: Optional[Event] = None


class CommandQueue:
    """cl_command_queue analogue: a DAG scheduler over one device.

    Parameters
    ----------
    device:
        The :class:`~repro.runtime.platform.Device` commands execute on
        (and whose compilation cache ``enqueue_kernel`` compiles through).
    out_of_order:
        ``False`` (default) chains every command after the previous one —
        clCreateCommandQueue without
        ``CL_QUEUE_OUT_OF_ORDER_EXEC_MODE_ENABLE``.  ``True`` runs any
        command whose ``wait_for`` list is resolved, concurrently up to
        ``workers``.
    workers:
        Size of the worker pool (the pthread-driver launcher threads).
    """

    def __init__(self, device: Device, out_of_order: bool = False,
                 workers: int = 2):
        self.device = device
        self.out_of_order = out_of_order
        self._pool = ThreadPoolExecutor(max_workers=workers)
        self._lock = threading.Lock()
        self._pending: List[_Command] = []     # enqueued, not yet flushed
        self._issued: List[Event] = []         # all live events (for finish)
        self._last_event: Optional[Event] = None
        self._ooo_barrier: Optional[Event] = None
        self._launches = 0
        self._compiles0 = device.compile_cache.stats.compiles

    # -- introspection -----------------------------------------------------------
    @property
    def stats(self) -> Dict[str, int]:
        """Launch count + pipeline compiles that hit this queue's *device*
        cache since queue creation.  The compile counter is device-wide:
        other queues (or direct ``build_kernel`` calls) on the same device
        contribute, and an autotuned device compiles one candidate per
        target on first launch.  Compiles are single-flight, so for a
        single queue on a static-target device the steady state is exactly
        1 per distinct kernel/local-size."""
        with self._lock:
            launches = self._launches
        return {"launches": launches,
                "enqueue_compiles":
                    self.device.compile_cache.stats.compiles
                    - self._compiles0}

    def events(self) -> List[Event]:
        """Snapshot of live (not yet pruned) events, in enqueue order."""
        with self._lock:
            return list(self._issued)

    # -- enqueue APIs -------------------------------------------------------------
    def _enqueue(self, name: str, fn: Callable[[], None],
                 wait_for: Optional[Sequence[Event]],
                 kind: str = "command") -> Event:
        """Core enqueue: record a command node and return its event.

        The full ``wait_for`` list is always preserved on the command (an
        in-order queue *adds* the previous command, it never replaces the
        explicit list)."""
        ev = Event(name, queue=self, kind=kind)
        deps = list(wait_for or [])
        with self._lock:
            if not self.out_of_order and self._last_event is not None:
                deps.append(self._last_event)
            if self.out_of_order and self._ooo_barrier is not None:
                if self._ooo_barrier.succeeded:
                    # a completed barrier gates nothing anymore; clearing
                    # it keeps long-lived queues at zero steady-state cost
                    # (a FAILED barrier stays: dependents must still fail)
                    self._ooo_barrier = None
                else:
                    deps.append(self._ooo_barrier)
            cmd = _Command(fn, ev, deps)
            self._pending.append(cmd)
            self._last_event = ev
            self._issued.append(ev)
        return ev

    def enqueue_native(self, fn: Callable[[], None],
                       wait_for: Optional[Sequence[Event]] = None,
                       name: str = "native", kind: str = "native") -> Event:
        """clEnqueueNativeKernel analogue: run a host function as a DAG
        node.  The serving engine and the multi-device scheduler build
        their pipelines out of these."""
        return self._enqueue(name, fn, wait_for, kind=kind)

    @staticmethod
    def _check_not_mapped(buf, what: str) -> None:
        """Reject a device-side write over any active mapped region of
        the buffer's root allocation: replacing the payload would
        silently detach the zero-copy views (the host/device race OpenCL
        leaves undefined is an error here, matching the launch guard)."""
        root = buf.root
        lo, hi = buf.origin, buf.origin + buf.nbytes
        with root._map_lock:
            for m in root._maps:
                if m.overlaps(lo, hi):
                    raise MapError(
                        f"{what} overlaps active map {m!r}; unmap before "
                        f"writing the buffer from the device side")

    def enqueue_write_buffer(self, buf: Buffer, host: np.ndarray,
                             wait_for=None) -> Event:
        """clEnqueueWriteBuffer: copy ``host`` into the device buffer
        (for a sub-buffer, in place into the parent's span) and publish
        the write to the residency tracker."""
        def run():
            self._check_not_mapped(buf, "write_buffer")
            buf.data = np.array(host, dtype=buf.dtype, copy=True)
            buf.mark_written()
        return self._enqueue("write", run, wait_for, kind="transfer")

    def enqueue_read_buffer(self, buf: Buffer, out: np.ndarray,
                            wait_for=None) -> Event:
        """clEnqueueReadBuffer: copy the device buffer into ``out``."""
        def run():
            out[...] = buf.data
        return self._enqueue("read", run, wait_for, kind="transfer")

    # -- zero-copy host access (clEnqueueMapBuffer, OpenCL §5.4.2) --------------
    def enqueue_map_buffer(self, buf, flags: str = MAP_READ_WRITE,
                           offset: int = 0, nbytes: Optional[int] = None,
                           wait_for: Optional[Sequence[Event]] = None
                           ) -> MappedRegion:
        """clEnqueueMapBuffer: map ``[offset, offset + nbytes)`` of the
        buffer (or sub-buffer) for host access as a DAG command.

        Returns a :class:`~repro.runtime.memory.MappedRegion` whose
        ``event`` completes when the mapping is established; completion
        *publishes* ``region.array``, a zero-copy ndarray view into the
        buffer payload (``region.get()`` waits and returns it).  Flags:
        ``"r"``, ``"w"``, ``"rw"``, or ``"wi"``
        (CL_MAP_WRITE_INVALIDATE_REGION) — a write-invalidate map skips
        the read-back sync hook because its contents are undefined until
        the host writes them.

        Map rules (checked when the command runs, so violations
        propagate as failed events): any number of overlapping *read*
        maps may coexist; a *write* map must not overlap any other
        active map of the same root allocation."""
        region = MappedRegion(buf, offset,
                              buf.nbytes - offset if nbytes is None
                              else nbytes, flags)

        def run():
            root = buf.root
            lo, hi = region.abs_span
            with root._map_lock:
                for m in root._maps:
                    if m.overlaps(lo, hi) and (m.writable
                                               or region.writable):
                        raise MapError(
                            f"map {region.flags!r} [{lo}, {hi}) overlaps "
                            f"active map {m!r} of the same allocation")
                root._maps.append(region)
                region._active = True
            try:
                if region.flags != MAP_WRITE_INVALIDATE and \
                        root.on_map_sync is not None:
                    # read-back: make the payload current before
                    # publishing (skipped for WRITE_INVALIDATE)
                    root.on_map_sync(lo, hi)
                first = offset // buf.itemsize
                region.array = _flat_view(buf.data)[
                    first:first + region.nbytes // buf.itemsize]
            except BaseException:
                # roll the registration back: a failed map must not
                # leave a zombie region blocking the span forever
                with root._map_lock:
                    if region in root._maps:
                        root._maps.remove(region)
                    region._active = False
                raise

        region.event = self._enqueue(
            f"map:{flags}:{region.abs_span[0]}-{region.abs_span[1]}",
            run, wait_for, kind="map")
        return region

    def enqueue_unmap_buffer(self, region: MappedRegion,
                             wait_for: Optional[Sequence[Event]] = None
                             ) -> Event:
        """clEnqueueUnmapMemObject: retire a mapped region as a DAG
        command.  For write-flagged maps, completion publishes the span
        to the residency tracker (other device copies become stale over
        exactly the mapped span); the zero-copy view is invalidated."""
        def run():
            root = region.buf.root
            with root._map_lock:
                if not region._active:
                    raise MapError(f"unmap of inactive region {region!r}")
                root._maps.remove(region)
                region._active = False
            if region.writable:
                region.buf.mark_written_span(region.offset,
                                             region.offset + region.nbytes)
            region.array = None

        ev = self._enqueue(
            f"unmap:{region.abs_span[0]}-{region.abs_span[1]}",
            run, wait_for, kind="map")
        region.unmap_event = ev
        return ev

    def enqueue_ndrange_kernel(self, kernel: CompiledKernel,
                               global_size: Sequence[int],
                               buffers: Dict[str, Buffer],
                               scalars: Optional[Dict[str, object]] = None,
                               wait_for=None,
                               group_range: Optional[Tuple[int, int]] = None
                               ) -> Event:
        """clEnqueueNDRangeKernel: launch a pre-compiled kernel.

        ``group_range=(lo, hi)`` restricts execution to a contiguous range
        of linearized work-groups of the *full* NDRange — the co-execution
        unit the multi-device scheduler fans out
        (:mod:`repro.runtime.scheduler`)."""
        def run():
            self._launch(kernel, buffers, global_size, scalars, group_range)
        return self._enqueue(f"ndrange:{kernel.name}", run, wait_for,
                             kind="kernel")

    def enqueue_nd_range(self, kernel: Kernel,
                         global_size: Sequence[int],
                         local_size: Sequence[int],
                         wait_for: Optional[Sequence[Event]] = None,
                         group_range: Optional[Tuple[int, int]] = None,
                         target: Optional[str] = None) -> Event:
        """clEnqueueNDRangeKernel over a first-class
        :class:`~repro.core.program.Kernel` object (docs/host_api.md).

        Arguments were bound with ``kernel.set_arg``/``set_args`` and
        must be device-resident :class:`Buffer`/:class:`~repro.runtime.
        memory.SubBuffer` objects; they are validated and *snapshotted
        now* (OpenCL: an enqueue captures the kernel's current
        arguments, so mutating or cloning the kernel afterwards never
        races the command).  Specialization for ``local_size`` on this
        queue's device happens when the command runs — the paper's
        enqueue-time work-group-function compilation (§4.1), memoized in
        the device cache, so only the first enqueue compiles."""
        buffers, scalars = kernel.launch_args(accept=("device",))

        def run():
            binary = kernel.bind(self.device, local_size, target=target)
            self._launch(binary, buffers, global_size, scalars,
                         group_range)
        return self._enqueue(f"ndrange:{kernel.name}", run, wait_for,
                             kind="kernel")

    def enqueue_kernel(self, build, local_size: Sequence[int],
                       global_size: Sequence[int],
                       buffers: Dict[str, Buffer],
                       scalars: Optional[Dict[str, object]] = None,
                       wait_for=None, **opts) -> Event:
        """Deprecated host entry point: compile ``build`` at enqueue
        time and launch it.  Superseded by binding arguments on a
        :class:`~repro.core.program.Kernel` and calling
        :meth:`enqueue_nd_range` — same enqueue-time specialization,
        same device cache, plus typed argument validation."""
        warnings.warn(
            "CommandQueue.enqueue_kernel() is deprecated; create a "
            "Program/Kernel via Context and use enqueue_nd_range "
            "(docs/host_api.md)", DeprecationWarning, stacklevel=2)

        def run():
            kernel = self.device.compile(build, local_size, **opts)
            self._launch(kernel, buffers, global_size, scalars, None)
        return self._enqueue("ndrange:<enqueue-compiled>", run, wait_for,
                             kind="kernel")

    def _launch(self, kernel, buffers: Dict[str, Buffer], global_size,
                scalars, group_range) -> None:
        """Run a compiled kernel over device buffers and write back.

        Buffers may be root :class:`Buffer`\\ s or
        :class:`~repro.runtime.memory.SubBuffer` views; a view's
        write-back lands in place in the parent's span.  Launching over a
        buffer whose root allocation has *any* active mapped region is
        rejected: the kernel's write-back would race with (or silently
        detach) the zero-copy host view — undefined in OpenCL, an error
        here."""
        with self._lock:
            self._launches += 1
        for name, b in buffers.items():
            self._check_not_mapped(b, f"kernel argument {name!r}")
        arrs = {k: b.data for k, b in buffers.items()}
        # aliasing: when two arguments share one root allocation
        # (overlapping sub-buffers), writing every result back would
        # clobber one view's fresh writes with the other view's stale
        # snapshot — real kernels only store what they wrote.  Snapshot
        # the aliased arguments so unchanged views can skip write-back
        # (independent arguments keep the cheap unconditional path).
        roots: Dict[int, int] = {}
        for b in buffers.values():
            roots[id(b.root)] = roots.get(id(b.root), 0) + 1
        shared_root = {k for k, b in buffers.items()
                       if roots[id(b.root)] > 1}
        snaps = {k: np.array(arrs[k], copy=True) for k in shared_root}
        if group_range is None:
            out = kernel(arrs, global_size, scalars)
        else:
            out = kernel(arrs, global_size, scalars,
                         group_range=group_range)
        for k, b in buffers.items():
            if k in shared_root and \
                    np.array_equal(np.asarray(out[k]), snaps[k]):
                continue            # observably unwritten aliased view
            b.data = out[k]
            # conservative write publication: without kernel-side access
            # metadata every written-back buffer counts as written
            # (OpenCL makes the same assumption for cl_mem without
            # read-only flags)
            b.mark_written()

    def enqueue_marker(self, wait_for: Optional[Sequence[Event]] = None
                       ) -> Event:
        """clEnqueueMarkerWithWaitList: an empty command that completes
        when ``wait_for`` does — or, with no list, when everything
        enqueued so far has completed.  Markers do not block later
        commands; use them to hand one queue's progress to another."""
        if wait_for is None:
            with self._lock:
                # every live previously-enqueued command: still-pending,
                # flushed-but-running, or complete (resolves instantly)
                wait_for = list(self._issued)
        return self._enqueue("marker", lambda: None, wait_for,
                             kind="marker")

    def enqueue_barrier(self, wait_for: Optional[Sequence[Event]] = None
                        ) -> Event:
        """clEnqueueBarrierWithWaitList: like a marker, but on an
        out-of-order queue every *subsequently enqueued* command also
        waits for it — a synchronization point splitting the DAG into
        before/after."""
        ev = self.enqueue_marker(wait_for)
        ev.name = "queue-barrier"
        if self.out_of_order:
            with self._lock:
                self._ooo_barrier = ev
        return ev

    # -- DAG execution ------------------------------------------------------------
    def flush(self) -> None:
        """clFlush: submit the DAG built so far and return immediately.

        Every command enqueued before this call is *armed*: commands with
        resolved wait lists go to the worker pool now, the rest are
        submitted automatically (from the completing thread) as their
        dependencies finish.  Completion is observed with ``finish()`` or
        ``Event.wait()``."""
        with self._lock:
            armed, self._pending = self._pending, []
            # successfully completed events need no further tracking;
            # pruning keeps _issued bounded on long-lived queues.  Failed
            # events stay until the next finish() reports them.
            self._issued = [e for e in self._issued if not e.succeeded]
            self._issued.extend(c.event for c in armed)
        for cmd in armed:
            self._arm(cmd)

    def _arm(self, cmd: _Command) -> None:
        """Register dependency callbacks; submit if already ready."""
        cmd.remaining = len(cmd.deps)
        if cmd.remaining == 0:
            self._submit(cmd)
            return
        for dep in cmd.deps:
            # fires immediately if the dep is already terminal
            dep.add_callback(lambda ev, c=cmd: self._dep_resolved(c, ev))

    def _dep_resolved(self, cmd: _Command, dep: Event) -> None:
        with self._lock:
            if dep.failed and cmd.failed_dep is None:
                cmd.failed_dep = dep
            cmd.remaining -= 1
            ready = cmd.remaining == 0 and not cmd.submitted
            if ready:
                cmd.submitted = True
        if ready:
            self._submit(cmd)

    def _submit(self, cmd: _Command) -> None:
        cmd.event._transition(EventStatus.SUBMITTED)
        self._pool.submit(self._run_command, cmd)

    def _run_command(self, cmd: _Command) -> None:
        if cmd.failed_dep is not None:
            cmd.event.fail(DependencyError(
                f"command {cmd.event.name!r} abandoned: dependency "
                f"{cmd.failed_dep.name!r} failed"))
            return
        cmd.event._transition(EventStatus.RUNNING)
        try:
            cmd.fn()
        except BaseException as e:  # noqa: BLE001 - must reach waiters
            cmd.event.fail(e)
        else:
            cmd.event.complete()

    def finish(self, timeout: Optional[float] = None) -> None:
        """clFinish: flush and wait for completion of *every* issued
        command.  (Waiting only on the last event is wrong for
        out-of-order queues: the last-enqueued command can finish while
        earlier independent commands are still executing.)

        Raises :class:`CommandError` if any command failed, or
        ``RuntimeError`` if ``timeout`` (seconds) expires — e.g. a wait
        list references an event of a queue that was never flushed, or an
        incomplete :class:`~repro.runtime.events.UserEvent`."""
        self.flush()
        with self._lock:
            issued = list(self._issued)
        try:
            if not wait_for_events(issued, timeout):
                stuck = [e.name for e in issued if not e.done]
                raise RuntimeError(
                    f"CommandQueue.finish timed out after {timeout}s; "
                    f"incomplete commands: {stuck[:8]}")
        finally:
            with self._lock:
                self._issued = [e for e in self._issued if not e.done]

    def __del__(self):  # pragma: no cover - interpreter shutdown ordering
        try:
            self._pool.shutdown(wait=False)
        except Exception:
            pass


__all__ = ["CommandQueue", "Event", "EventStatus", "UserEvent",
           "CommandError", "DependencyError", "MapError", "MappedRegion",
           "wait_for_events"]
