"""Command queues over an explicit event dependency DAG (paper §2/§3).

Commands (kernel launches, buffer reads/writes, native host functions) are
enqueued with optional ``wait_for`` event lists and return an
:class:`~repro.runtime.events.Event`.  In-order queues add an implicit
dependency on the previously enqueued command; out-of-order queues execute
any command whose dependencies are resolved — the paper's observation that
commands in an out-of-order queue "can be assumed to be independent of each
other unless explicitly synchronized using events".

Scheduling is **push-based**: ``flush()`` submits every flushed command
whose wait list is already resolved, and each event completion decrements
its dependents' outstanding-dependency counters, submitting newly-ready
commands from the completing thread — no polling loop.  The worker pool
plays the role of pocl's pthread-driver launcher threads; cross-queue and
cross-device dependencies work because the resolution mechanism is the
event itself, not queue-local state.

Every event moves QUEUED -> SUBMITTED -> RUNNING -> COMPLETE with
nanosecond profiling timestamps (docs/runtime.md maps each call here to
its OpenCL counterpart).  A failing command terminates its event with the
error and every transitive dependent fails with ``DependencyError``
without running.

``enqueue_kernel`` is the pocl-faithful enqueue path: the work-group
function is specialized at enqueue time (paper §4.1) through the device's
compilation cache — the first enqueue compiles, every later enqueue of the
same kernel/local-size is a hash lookup.  ``self.stats`` counts launches
and enqueue-time compiles for the dispatch-overhead story.

``enqueue_map_buffer``/``enqueue_unmap_buffer`` put zero-copy host access
on the same DAG (docs/memory.md): the map event's completion publishes an
ndarray view into the buffer payload, the unmap publishes write spans to
the residency tracker, and launches (or device-side writes) over an
allocation with *any* active map are rejected — the write-back would
race with or silently detach the zero-copy host view.  Kernel launches
accept sub-buffer views anywhere a buffer is accepted, with in-place
write-back into the parent's span.

**Kernel fusion** (docs/runtime.md §Kernel fusion): because the queue
sees the whole pending DAG before execution, ``flush()`` runs a graph
optimizer over the enqueue window: adjacent producer→consumer chains of
elementwise kernels (same NDRange, the consumer's only dependence on the
producer a buffer it wrote, every region ``wi_parallel``) are rewritten
into ONE stitched command (:mod:`repro.core.fusion`), eliding
intermediate buffers whose only use was the stitched-away link.  The
original per-kernel events stay live — they complete when the fused
command does, sharing its profiling counters — so dependents and
``finish()`` observe an unchanged DAG.  ``fusion="off"|"flush"|"eager"``
selects the mode per queue; ``REPRO_FUSE=0`` kills it process-wide.
"""

from __future__ import annotations

import os
import threading
import warnings
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.api import CompiledKernel
from ..core.errors import InvalidArgError
from ..core.fusion import (ChainEdge, FusionError, build_fused_spec,
                           make_fused_key)
from ..core.passes import KernelFusibility, kernel_fusibility
from ..core.program import Kernel
from .events import (CommandError, DependencyError, Event, EventStatus,
                     UserEvent, wait_for_events)
from .memory import (MAP_READ_WRITE, MAP_WRITE_INVALIDATE, MapError,
                     MappedRegion, _flat_view)
from .platform import Buffer, Device

#: queue fusion modes: "off" never rewrites, "flush" rewrites the window
#: at flush()/finish() time, "eager" additionally pre-stitches the
#: growing chain during the enqueue window (warm caches before flush)
FUSION_MODES = ("off", "flush", "eager")


def _fusion_enabled() -> bool:
    """The REPRO_FUSE kill-switch, read at fusion time (not import time)
    so tests and operators can flip it per call."""
    return os.environ.get("REPRO_FUSE", "1") != "0"


class _Command:
    """One node of the DAG: a host thunk plus its event and wait list."""

    __slots__ = ("fn", "event", "deps", "remaining", "submitted",
                 "failed_dep", "meta")

    def __init__(self, fn: Callable[[], None], event: Event,
                 deps: Sequence[Event], meta=None):
        self.fn = fn
        self.event = event
        self.deps: List[Event] = list(deps)
        self.remaining = 0            # unresolved deps (set when armed)
        self.submitted = False
        self.failed_dep: Optional[Event] = None
        # what the fusion matcher knows about this command: a
        # _KernelLaunch (fusible), a _BufferUse (transfer/map — names the
        # buffers it touches), or None (opaque: native/deprecated paths)
        self.meta = meta


class _KernelLaunch:
    """Fusion-matcher metadata for one enqueue_nd_range command: the
    argument snapshot plus the launch geometry, enough to re-stitch the
    kernel from its program's IR builder."""

    __slots__ = ("kernel", "buffers", "scalars", "global_size",
                 "local_size", "target", "group_range")

    def __init__(self, kernel: Kernel, buffers: Dict[str, object],
                 scalars: Dict[str, object], global_size, local_size,
                 target, group_range):
        self.kernel = kernel
        self.buffers = buffers
        self.scalars = scalars
        self.global_size = tuple(global_size)
        self.local_size = tuple(local_size)
        self.target = target
        self.group_range = group_range


class _BufferUse:
    """Fusion-matcher metadata for a non-kernel command that touches
    buffers (transfers, maps): elision legality needs to see *every*
    in-window observer of an intermediate."""

    __slots__ = ("buffers",)

    def __init__(self, *buffers):
        self.buffers = buffers


#: per-ir_hash fusibility facts (kernels are content-addressed, so the
#: facts are process-global); computed from the program's unmutated
#: signature IR — explicit barriers/loops/footprints are all visible
#: there, before normalize adds the implicit region barriers
_fusibility_facts: Dict[str, KernelFusibility] = {}


def _facts_for(kernel: Kernel) -> KernelFusibility:
    h = kernel.ir_hash
    facts = _fusibility_facts.get(h)
    if facts is None:
        facts = kernel_fusibility(kernel.program.function(kernel.name))
        _fusibility_facts[h] = facts
    return facts


class CommandQueue:
    """cl_command_queue analogue: a DAG scheduler over one device.

    Parameters
    ----------
    device:
        The :class:`~repro.runtime.platform.Device` commands execute on
        (and whose compilation cache ``enqueue_kernel`` compiles through).
    out_of_order:
        ``False`` (default) chains every command after the previous one —
        clCreateCommandQueue without
        ``CL_QUEUE_OUT_OF_ORDER_EXEC_MODE_ENABLE``.  ``True`` runs any
        command whose ``wait_for`` list is resolved, concurrently up to
        ``workers``.
    workers:
        Size of the worker pool (the pthread-driver launcher threads).
    fusion:
        DAG-fusion mode: ``"off"`` (never rewrite), ``"flush"``
        (default — rewrite the window when it is flushed), or
        ``"eager"`` (also pre-stitch the growing chain at enqueue time,
        so the flush-time rewrite is pure cache hits).  The
        ``REPRO_FUSE=0`` environment kill-switch overrides all modes.
    """

    def __init__(self, device: Device, out_of_order: bool = False,
                 workers: int = 2, fusion: str = "flush"):
        if fusion not in FUSION_MODES:
            raise InvalidArgError(
                f"fusion mode {fusion!r} not in {FUSION_MODES}")
        self.device = device
        self.out_of_order = out_of_order
        self.fusion = fusion
        #: optional live event subscriber (duck-typed ``on_command(event,
        #: deps, queue)``) — the Chrome-trace collector
        #: (:class:`~repro.runtime.trace.ChromeTrace`) attaches here;
        #: ``None`` keeps the enqueue path at zero extra cost
        self.trace_sink = None
        self._pool = ThreadPoolExecutor(max_workers=workers)
        self._lock = threading.Lock()
        self._pending: List[_Command] = []     # enqueued, not yet flushed
        self._armed: set = set()               # flushed, deps unresolved
        self._issued: List[Event] = []         # all live events (for finish)
        self._last_event: Optional[Event] = None
        self._ooo_barrier: Optional[Event] = None
        self._launches = 0
        self._compiles0 = device.compile_cache.stats.compiles
        self._fused_chains = 0
        self._commands_eliminated = 0
        self._bytes_elided = 0

    # -- introspection -----------------------------------------------------------
    @property
    def stats(self) -> Dict[str, int]:
        """Launch count + pipeline compiles that hit this queue's *device*
        cache since queue creation.  The compile counter is device-wide:
        other queues (or direct ``build_kernel`` calls) on the same device
        contribute, and an autotuned device compiles one candidate per
        target on first launch.  Compiles are single-flight, so for a
        single queue on a static-target device the steady state is exactly
        1 per distinct kernel/local-size."""
        with self._lock:
            launches = self._launches
        return {"launches": launches,
                "enqueue_compiles":
                    self.device.compile_cache.stats.compiles
                    - self._compiles0}

    def events(self) -> List[Event]:
        """Snapshot of live (not yet pruned) events, in enqueue order."""
        with self._lock:
            return list(self._issued)

    def dag_stats(self) -> Dict[str, object]:
        """Counters of the DAG fusion rewrite (docs/runtime.md §Kernel
        fusion): chains stitched, commands removed from the executed DAG
        (original events still complete), and bytes of memory traffic
        elided — one avoided store plus one avoided load per elided
        intermediate buffer."""
        with self._lock:
            return {"mode": self.fusion,
                    "fused_chains": self._fused_chains,
                    "commands_eliminated": self._commands_eliminated,
                    "bytes_elided": self._bytes_elided}

    # -- enqueue APIs -------------------------------------------------------------
    def _enqueue(self, name: str, fn: Callable[[], None],
                 wait_for: Optional[Sequence[Event]],
                 kind: str = "command", meta=None) -> Event:
        """Core enqueue: record a command node and return its event.

        The full ``wait_for`` list is always preserved on the command (an
        in-order queue *adds* the previous command, it never replaces the
        explicit list)."""
        ev = Event(name, queue=self, kind=kind)
        deps = list(wait_for or [])
        with self._lock:
            if not self.out_of_order and self._last_event is not None:
                deps.append(self._last_event)
            if self.out_of_order and self._ooo_barrier is not None:
                if self._ooo_barrier.succeeded:
                    # a completed barrier gates nothing anymore; clearing
                    # it keeps long-lived queues at zero steady-state cost
                    # (a FAILED barrier stays: dependents must still fail)
                    self._ooo_barrier = None
                else:
                    deps.append(self._ooo_barrier)
            cmd = _Command(fn, ev, deps, meta=meta)
            self._pending.append(cmd)
            self._last_event = ev
            self._issued.append(ev)
        sink = self.trace_sink
        if sink is not None:
            sink.on_command(ev, cmd.deps, self)
        if self.fusion == "eager" and isinstance(meta, _KernelLaunch) \
                and _fusion_enabled():
            self._warm_eager()
        return ev

    def enqueue_native(self, fn: Callable[[], None],
                       wait_for: Optional[Sequence[Event]] = None,
                       name: str = "native", kind: str = "native") -> Event:
        """clEnqueueNativeKernel analogue: run a host function as a DAG
        node.  The serving engine and the multi-device scheduler build
        their pipelines out of these."""
        return self._enqueue(name, fn, wait_for, kind=kind)

    @staticmethod
    def _check_not_mapped(buf, what: str) -> None:
        """Reject a device-side write over any active mapped region of
        the buffer's root allocation: replacing the payload would
        silently detach the zero-copy views (the host/device race OpenCL
        leaves undefined is an error here, matching the launch guard)."""
        root = buf.root
        lo, hi = buf.origin, buf.origin + buf.nbytes
        with root._map_lock:
            for m in root._maps:
                if m.overlaps(lo, hi):
                    raise MapError(
                        f"{what} overlaps active map {m!r}; unmap before "
                        f"writing the buffer from the device side")

    def enqueue_write_buffer(self, buf: Buffer, host: np.ndarray,
                             wait_for=None) -> Event:
        """clEnqueueWriteBuffer: copy ``host`` into the device buffer
        (for a sub-buffer, in place into the parent's span) and publish
        the write to the residency tracker."""
        def run():
            self._check_not_mapped(buf, "write_buffer")
            buf.data = np.array(host, dtype=buf.dtype, copy=True)
            buf.mark_written()
        return self._enqueue("write", run, wait_for, kind="transfer",
                             meta=_BufferUse(buf))

    def enqueue_read_buffer(self, buf: Buffer, out: np.ndarray,
                            wait_for=None) -> Event:
        """clEnqueueReadBuffer: copy the device buffer into ``out``."""
        def run():
            out[...] = buf.data
        return self._enqueue("read", run, wait_for, kind="transfer",
                             meta=_BufferUse(buf))

    # -- zero-copy host access (clEnqueueMapBuffer, OpenCL §5.4.2) --------------
    def enqueue_map_buffer(self, buf, flags: str = MAP_READ_WRITE,
                           offset: int = 0, nbytes: Optional[int] = None,
                           wait_for: Optional[Sequence[Event]] = None
                           ) -> MappedRegion:
        """clEnqueueMapBuffer: map ``[offset, offset + nbytes)`` of the
        buffer (or sub-buffer) for host access as a DAG command.

        Returns a :class:`~repro.runtime.memory.MappedRegion` whose
        ``event`` completes when the mapping is established; completion
        *publishes* ``region.array``, a zero-copy ndarray view into the
        buffer payload (``region.get()`` waits and returns it).  Flags:
        ``"r"``, ``"w"``, ``"rw"``, or ``"wi"``
        (CL_MAP_WRITE_INVALIDATE_REGION) — a write-invalidate map skips
        the read-back sync hook because its contents are undefined until
        the host writes them.

        Map rules (checked when the command runs, so violations
        propagate as failed events): any number of overlapping *read*
        maps may coexist; a *write* map must not overlap any other
        active map of the same root allocation."""
        region = MappedRegion(buf, offset,
                              buf.nbytes - offset if nbytes is None
                              else nbytes, flags)

        def run():
            root = buf.root
            lo, hi = region.abs_span
            with root._map_lock:
                for m in root._maps:
                    if m.overlaps(lo, hi) and (m.writable
                                               or region.writable):
                        raise MapError(
                            f"map {region.flags!r} [{lo}, {hi}) overlaps "
                            f"active map {m!r} of the same allocation")
                root._maps.append(region)
                region._active = True
            try:
                if region.flags != MAP_WRITE_INVALIDATE and \
                        root.on_map_sync is not None:
                    # read-back: make the payload current before
                    # publishing (skipped for WRITE_INVALIDATE)
                    root.on_map_sync(lo, hi)
                first = offset // buf.itemsize
                region.array = _flat_view(buf.data)[
                    first:first + region.nbytes // buf.itemsize]
            except BaseException:
                # roll the registration back: a failed map must not
                # leave a zombie region blocking the span forever
                with root._map_lock:
                    if region in root._maps:
                        root._maps.remove(region)
                    region._active = False
                raise

        region.event = self._enqueue(
            f"map:{flags}:{region.abs_span[0]}-{region.abs_span[1]}",
            run, wait_for, kind="map", meta=_BufferUse(buf))
        return region

    def enqueue_unmap_buffer(self, region: MappedRegion,
                             wait_for: Optional[Sequence[Event]] = None
                             ) -> Event:
        """clEnqueueUnmapMemObject: retire a mapped region as a DAG
        command.  For write-flagged maps, completion publishes the span
        to the residency tracker (other device copies become stale over
        exactly the mapped span); the zero-copy view is invalidated."""
        def run():
            root = region.buf.root
            with root._map_lock:
                if not region._active:
                    raise MapError(f"unmap of inactive region {region!r}")
                root._maps.remove(region)
                region._active = False
            if region.writable:
                region.buf.mark_written_span(region.offset,
                                             region.offset + region.nbytes)
            region.array = None

        ev = self._enqueue(
            f"unmap:{region.abs_span[0]}-{region.abs_span[1]}",
            run, wait_for, kind="map", meta=_BufferUse(region.buf))
        region.unmap_event = ev
        return ev

    def enqueue_ndrange_kernel(self, kernel: CompiledKernel,
                               global_size: Sequence[int],
                               buffers: Dict[str, Buffer],
                               scalars: Optional[Dict[str, object]] = None,
                               wait_for=None,
                               group_range: Optional[Tuple[int, int]] = None
                               ) -> Event:
        """clEnqueueNDRangeKernel: launch a pre-compiled kernel.

        ``group_range=(lo, hi)`` restricts execution to a contiguous range
        of linearized work-groups of the *full* NDRange — the co-execution
        unit the multi-device scheduler fans out
        (:mod:`repro.runtime.scheduler`)."""
        def run():
            self._launch(kernel, buffers, global_size, scalars, group_range)
        return self._enqueue(f"ndrange:{kernel.name}", run, wait_for,
                             kind="kernel")

    def enqueue_nd_range(self, kernel: Kernel,
                         global_size: Sequence[int],
                         local_size: Sequence[int],
                         wait_for: Optional[Sequence[Event]] = None,
                         group_range: Optional[Tuple[int, int]] = None,
                         target: Optional[str] = None) -> Event:
        """clEnqueueNDRangeKernel over a first-class
        :class:`~repro.core.program.Kernel` object (docs/host_api.md).

        Arguments were bound with ``kernel.set_arg``/``set_args`` and
        must be device-resident :class:`Buffer`/:class:`~repro.runtime.
        memory.SubBuffer` objects; they are validated and *snapshotted
        now* (OpenCL: an enqueue captures the kernel's current
        arguments, so mutating or cloning the kernel afterwards never
        races the command).  Specialization for ``local_size`` on this
        queue's device happens when the command runs — the paper's
        enqueue-time work-group-function compilation (§4.1), memoized in
        the device cache, so only the first enqueue compiles."""
        buffers, scalars = kernel.launch_args(accept=("device",))
        meta = _KernelLaunch(kernel, buffers, scalars, global_size,
                             local_size, target, group_range)

        def run():
            binary = kernel.bind(self.device, local_size, target=target)
            self._launch(binary, buffers, global_size, scalars,
                         group_range)
        return self._enqueue(f"ndrange:{kernel.name}", run, wait_for,
                             kind="kernel", meta=meta)

    def enqueue_kernel(self, build, local_size: Sequence[int],
                       global_size: Sequence[int],
                       buffers: Dict[str, Buffer],
                       scalars: Optional[Dict[str, object]] = None,
                       wait_for=None, **opts) -> Event:
        """Deprecated host entry point: compile ``build`` at enqueue
        time and launch it.  Superseded by binding arguments on a
        :class:`~repro.core.program.Kernel` and calling
        :meth:`enqueue_nd_range` — same enqueue-time specialization,
        same device cache, plus typed argument validation."""
        warnings.warn(
            "CommandQueue.enqueue_kernel() is deprecated; create a "
            "Program/Kernel via Context and use enqueue_nd_range "
            "(docs/host_api.md)", DeprecationWarning, stacklevel=2)

        def run():
            kernel = self.device.compile(build, local_size, **opts)
            self._launch(kernel, buffers, global_size, scalars, None)
        return self._enqueue("ndrange:<enqueue-compiled>", run, wait_for,
                             kind="kernel")

    def _launch(self, kernel, buffers: Dict[str, Buffer], global_size,
                scalars, group_range) -> None:
        """Run a compiled kernel over device buffers and write back.

        Buffers may be root :class:`Buffer`\\ s or
        :class:`~repro.runtime.memory.SubBuffer` views; a view's
        write-back lands in place in the parent's span.  Launching over a
        buffer whose root allocation has *any* active mapped region is
        rejected: the kernel's write-back would race with (or silently
        detach) the zero-copy host view — undefined in OpenCL, an error
        here."""
        with self._lock:
            self._launches += 1
        for name, b in buffers.items():
            self._check_not_mapped(b, f"kernel argument {name!r}")
        arrs = {k: b.data for k, b in buffers.items()}
        # aliasing: when two arguments share one root allocation
        # (overlapping sub-buffers), writing every result back would
        # clobber one view's fresh writes with the other view's stale
        # snapshot — real kernels only store what they wrote.  Snapshot
        # the aliased arguments so unchanged views can skip write-back
        # (independent arguments keep the cheap unconditional path).
        roots: Dict[int, int] = {}
        for b in buffers.values():
            roots[id(b.root)] = roots.get(id(b.root), 0) + 1
        shared_root = {k for k, b in buffers.items()
                       if roots[id(b.root)] > 1}
        snaps = {k: np.array(arrs[k], copy=True) for k in shared_root}
        if group_range is None:
            out = kernel(arrs, global_size, scalars)
        else:
            out = kernel(arrs, global_size, scalars,
                         group_range=group_range)
        for k, b in buffers.items():
            if k in shared_root and \
                    np.array_equal(np.asarray(out[k]), snaps[k]):
                continue            # observably unwritten aliased view
            b.data = out[k]
            # conservative write publication: without kernel-side access
            # metadata every written-back buffer counts as written
            # (OpenCL makes the same assumption for cl_mem without
            # read-only flags)
            b.mark_written()

    def enqueue_marker(self, wait_for: Optional[Sequence[Event]] = None
                       ) -> Event:
        """clEnqueueMarkerWithWaitList: an empty command that completes
        when ``wait_for`` does — or, with no list, when everything
        enqueued so far has completed.  Markers do not block later
        commands; use them to hand one queue's progress to another."""
        if wait_for is None:
            with self._lock:
                # every live previously-enqueued command: still-pending,
                # flushed-but-running, or complete (resolves instantly)
                wait_for = list(self._issued)
        return self._enqueue("marker", lambda: None, wait_for,
                             kind="marker")

    def enqueue_barrier(self, wait_for: Optional[Sequence[Event]] = None
                        ) -> Event:
        """clEnqueueBarrierWithWaitList: like a marker, but on an
        out-of-order queue every *subsequently enqueued* command also
        waits for it — a synchronization point splitting the DAG into
        before/after."""
        ev = self.enqueue_marker(wait_for)
        ev.name = "queue-barrier"
        if self.out_of_order:
            with self._lock:
                self._ooo_barrier = ev
        return ev

    # -- DAG fusion (the flush-time graph optimizer, docs/runtime.md) -----------
    def _edge_chained(self, prod: _Command, cons: _Command
                      ) -> Optional[List[Tuple[str, str, object]]]:
        """Is ``prod → cons`` a legal fusion edge?  Returns the chained
        buffers as ``(prod_arg, cons_arg, buffer)`` triples (non-empty),
        or ``None`` if the pair must not fuse.

        Legality (ISSUE/paper framing — the consumer's only dependence
        on the producer is a buffer the producer wrote, and both are
        pure per-work-item maps):

        * both commands are ``enqueue_nd_range`` launches with identical
          NDRange geometry, target, build options, and no group_range;
        * both kernels are elementwise (:func:`~repro.core.passes.
          kernel_fusibility`: 1-D, loop-free, barrier-free, every
          global access at ``global_id(0)`` — which also makes every
          region ``wi_parallel``);
        * the consumer waits on the producer, and its *other* deps are a
          subset of the producer's own deps (anything else could order
          between the two commands, or deadlock the fused node);
        * ≥1 chained buffer: the identical root Buffer object stored
          exactly once by the producer and only loaded by the consumer,
          unmapped, sized to the NDRange;
        * no cross-argument root aliasing (two distinct arg objects over
          one root allocation, e.g. sub-buffer views) when either kernel
          stores to that root — write-back interleaving would differ
          from the sequential schedule.
        """
        pm, cm = prod.meta, cons.meta
        if not (isinstance(pm, _KernelLaunch)
                and isinstance(cm, _KernelLaunch)):
            return None
        if (pm.global_size != cm.global_size
                or pm.local_size != cm.local_size
                or pm.target != cm.target
                or pm.group_range is not None
                or cm.group_range is not None
                or pm.kernel.program.options != cm.kernel.program.options
                or len(pm.global_size) != 1):
            return None
        if prod.event not in cons.deps:
            return None
        extra = [d for d in cons.deps if d is not prod.event]
        pdeps = set(id(d) for d in prod.deps)
        if any(id(d) not in pdeps for d in extra):
            return None
        pf, cf = _facts_for(pm.kernel), _facts_for(cm.kernel)
        if not (pf.elementwise and cf.elementwise):
            return None
        # root-aliasing audit across the pair
        stores_root = set()
        objs_per_root: Dict[int, set] = {}
        for m, facts in ((pm, pf), (cm, cf)):
            for arg, b in m.buffers.items():
                root = b.root
                objs_per_root.setdefault(id(root), set()).add(id(b))
                fp = facts.footprint(arg)
                if fp is not None and fp.stores:
                    stores_root.add(id(root))
        for rid, objs in objs_per_root.items():
            if len(objs) > 1 and rid in stores_root:
                return None
        chained: List[Tuple[str, str, object]] = []
        for parg, b in pm.buffers.items():
            pfp = pf.footprint(parg)
            if pfp is None or pfp.stores != 1 or not pfp.gid_only:
                continue
            if b.root is not b or b.map_count:
                continue
            if b.n_elems != pm.global_size[0]:
                continue
            for carg, cb in cm.buffers.items():
                if cb is not b:
                    continue
                cfp = cf.footprint(carg)
                if cfp is None or cfp.stores or not cfp.loads \
                        or not cfp.gid_only:
                    chained.clear()
                    return None   # consumer also writes/misuses it
                chained.append((parg, carg, b))
        return chained or None

    def _chain_runs(self, cmds: List[_Command]) -> List[Tuple[int, int]]:
        """Maximal runs ``[i, j]`` (inclusive) of adjacently-fusible
        commands in the window."""
        runs, i = [], 0
        while i < len(cmds):
            j = i
            while j + 1 < len(cmds) \
                    and self._edge_chained(cmds[j], cmds[j + 1]):
                j += 1
            if j > i:
                runs.append((i, j))
            i = j + 1
        return runs

    def _elidable(self, buf, prod_meta: _KernelLaunch,
                  window: List[_Command], chain: List[_Command],
                  seg: int) -> bool:
        """May the chained buffer be elided (never written, never
        allocated)?  Only when nothing else can observe it: it is a
        lazy, still-unmaterialized pool buffer, the producer never loads
        it, no *other* command in the window references its root, and no
        window command is opaque to the matcher (an unannotated native
        command could read anything)."""
        if not (isinstance(buf, Buffer) and buf._pool is not None
                and not buf.materialized):
            return False
        pfp = _facts_for(prod_meta.kernel).footprint(
            next(a for a, b in prod_meta.buffers.items() if b is buf))
        if pfp is None or pfp.loads:
            return False
        producer, consumer = chain[seg], chain[seg + 1]
        for cmd in window:
            if cmd is producer or cmd is consumer:
                continue
            m = cmd.meta
            if isinstance(m, _KernelLaunch):
                uses = m.buffers.values()
            elif isinstance(m, _BufferUse):
                uses = m.buffers
            elif cmd.event.kind == "marker":
                continue
            else:
                return False          # opaque command in the window
            if any(u.root is buf for u in uses):
                return False
        return True

    def _fuse_chain(self, chain: List[_Command],
                    window: List[_Command]) -> Optional[_Command]:
        """Rewrite ``chain`` (≥2 adjacently-fusible commands) into one
        stitched command, or ``None`` to fall back to unfused."""
        metas: List[_KernelLaunch] = [c.meta for c in chain]
        names = [m.kernel.name for m in metas]
        # alias groups: one fused parameter per distinct buffer object
        groups: Dict[int, List[Tuple[int, str]]] = {}
        for i, m in enumerate(metas):
            for arg, b in m.buffers.items():
                groups.setdefault(id(b), []).append((i, arg))
        alias_groups = [g for g in groups.values() if len(g) > 1]
        edges: List[ChainEdge] = []
        elided_bufs = []
        for seg in range(len(chain) - 1):
            for parg, carg, b in self._edge_chained(chain[seg],
                                                    chain[seg + 1]):
                elide = self._elidable(b, metas[seg], window, chain, seg)
                edges.append(ChainEdge(seg, seg + 1, parg, carg, elide))
                if elide:
                    elided_bufs.append(b)
        try:
            spec = build_fused_spec(
                [m.kernel.program.builder(m.kernel.name) for m in metas],
                names, edges, alias_groups,
                cache=self.device.compile_cache,
                key=make_fused_key([m.kernel.ir_hash for m in metas],
                                   edges, alias_groups,
                                   **metas[0].kernel.program.options),
                **metas[0].kernel.program.options)
        except FusionError:
            return None
        global_size = metas[0].global_size
        local_size = metas[0].local_size
        target = metas[0].target
        fev = Event("fused:" + "+".join(names), queue=self, kind="kernel")
        fev.fused_from = [c.event for c in chain]

        def run():
            binary = spec.program.binary_for(
                spec.kernel_name, local_size, device=self.device,
                target=target)
            fbufs, fscal = spec.bind_launch(
                [m.buffers for m in metas], [m.scalars for m in metas])
            self._launch(binary, fbufs, global_size, fscal, None)
            # an elided intermediate is never written, but residency
            # must read exactly as if the chain had run unfused
            for seg, arg in spec.elided:
                metas[seg].buffers[arg].mark_written()

        originals = [c.event for c in chain]

        def mirror(ev: Event) -> None:
            # the original per-kernel events complete with (and share
            # the profiling counters of) the fused command
            for o in originals:
                if ev.error is not None:
                    o.fail(ev.error)
                else:
                    o.complete()
                o.submit_ns = ev.submit_ns
                o.start_ns = ev.start_ns
                o.end_ns = ev.end_ns
        fev.add_callback(mirror)
        # deps: edge legality guarantees every later command's non-chain
        # deps are a subset of the head's, so the head's list is the
        # fused node's full wait list (and can never reach back into the
        # chain — no cycles through mirrored completions)
        fused_cmd = _Command(run, fev, chain[0].deps)
        sink = self.trace_sink
        if sink is not None:
            sink.on_command(fev, fused_cmd.deps, self)
        with self._lock:
            self._fused_chains += 1
            self._commands_eliminated += len(chain) - 1
            # one avoided write-back + one avoided read per elided edge
            self._bytes_elided += sum(2 * b.nbytes for b in elided_bufs)
        return fused_cmd

    def _fuse_window(self, cmds: List[_Command]) -> List[_Command]:
        """The flush-time graph optimizer: replace every maximal fusible
        chain in the window with one stitched command."""
        if self.fusion == "off" or not _fusion_enabled() \
                or len(cmds) < 2:
            return cmds
        runs = self._chain_runs(cmds)
        if not runs:
            return cmds
        out: List[_Command] = []
        pos = 0
        for i, j in runs:
            out.extend(cmds[pos:i])
            fused = self._fuse_chain(cmds[i:j + 1], cmds)
            if fused is not None:
                out.append(fused)
            else:
                out.extend(cmds[i:j + 1])
            pos = j + 1
        out.extend(cmds[pos:])
        return out

    def _warm_eager(self) -> None:
        """``fusion="eager"``: pre-stitch the growing pending tail chain
        during the enqueue window, so the flush-time rewrite (and its
        first launch) hits the fused tier instead of stitching."""
        with self._lock:
            window = list(self._pending)
        if len(window) < 2:
            return
        j = len(window) - 1
        i = j
        while i > 0 and self._edge_chained(window[i - 1], window[i]):
            i -= 1
        if i == j:
            return
        try:
            chain = window[i:j + 1]
            metas: List[_KernelLaunch] = [c.meta for c in chain]
            groups: Dict[int, List[Tuple[int, str]]] = {}
            for k, m in enumerate(metas):
                for arg, b in m.buffers.items():
                    groups.setdefault(id(b), []).append((k, arg))
            alias_groups = [g for g in groups.values() if len(g) > 1]
            edges = []
            for seg in range(len(chain) - 1):
                for parg, carg, b in self._edge_chained(chain[seg],
                                                        chain[seg + 1]):
                    edges.append(ChainEdge(
                        seg, seg + 1, parg, carg,
                        self._elidable(b, metas[seg], window, chain,
                                       seg)))
            build_fused_spec(
                [m.kernel.program.builder(m.kernel.name) for m in metas],
                [m.kernel.name for m in metas], edges, alias_groups,
                cache=self.device.compile_cache,
                key=make_fused_key([m.kernel.ir_hash for m in metas],
                                   edges, alias_groups,
                                   **metas[0].kernel.program.options),
                **metas[0].kernel.program.options)
        except FusionError:
            pass

    # -- DAG execution ------------------------------------------------------------
    def flush(self) -> None:
        """clFlush: submit the DAG built so far and return immediately.

        Every command enqueued before this call is *armed*: commands with
        resolved wait lists go to the worker pool now, the rest are
        submitted automatically (from the completing thread) as their
        dependencies finish.  Completion is observed with ``finish()`` or
        ``Event.wait()``.

        Before arming, the fusion rewrite runs over the window
        (:meth:`dag_stats`, docs/runtime.md §Kernel fusion) — fused
        chains arm as one command; their original events complete with
        it."""
        with self._lock:
            armed, self._pending = self._pending, []
        armed = self._fuse_window(armed)
        with self._lock:
            # successfully completed events need no further tracking;
            # pruning keeps _issued bounded on long-lived queues.  Failed
            # events stay until the next finish() reports them.
            self._issued = [e for e in self._issued if not e.succeeded]
            self._issued.extend(c.event for c in armed)
        for cmd in armed:
            self._arm(cmd)

    def _arm(self, cmd: _Command) -> None:
        """Register dependency callbacks; submit if already ready."""
        cmd.remaining = len(cmd.deps)
        if cmd.remaining == 0:
            with self._lock:
                cmd.submitted = True
            self._submit(cmd)
            return
        with self._lock:
            # tracked so cancel_pending can abandon a command whose
            # dependencies will never resolve (e.g. a lost device)
            self._armed.add(cmd)
        for dep in cmd.deps:
            # fires immediately if the dep is already terminal
            dep.add_callback(lambda ev, c=cmd: self._dep_resolved(c, ev))

    def _dep_resolved(self, cmd: _Command, dep: Event) -> None:
        with self._lock:
            if dep.failed and cmd.failed_dep is None:
                cmd.failed_dep = dep
            cmd.remaining -= 1
            ready = cmd.remaining == 0 and not cmd.submitted
            if ready:
                cmd.submitted = True
                self._armed.discard(cmd)
        if ready:
            self._submit(cmd)

    def _submit(self, cmd: _Command) -> None:
        if cmd.event.done:
            return                # cancelled while waiting on deps
        cmd.event._transition(EventStatus.SUBMITTED)
        self._pool.submit(self._run_command, cmd)

    def _run_command(self, cmd: _Command) -> None:
        if cmd.event.done:
            return                # cancelled between submit and run
        if cmd.failed_dep is not None:
            cmd.event.fail(DependencyError(
                f"command {cmd.event.name!r} abandoned: dependency "
                f"{cmd.failed_dep.name!r} failed"))
            return
        cmd.event._transition(EventStatus.RUNNING)
        try:
            cmd.fn()
        except BaseException as e:  # noqa: BLE001 - must reach waiters
            cmd.event.fail(e)
        else:
            cmd.event.complete()

    def cancel_pending(self, error: Optional[BaseException] = None
                       ) -> List[Event]:
        """Abandon every command that cannot have started running: the
        still-unflushed enqueue window plus armed commands whose wait
        lists are unresolved.  Their events fail with ``error`` (default
        a :class:`~repro.runtime.events.DependencyError`) without the
        command functions ever executing, so dependents fail typed and
        ``finish(timeout)`` observes them as *done*, never as stuck.

        This is the device-loss path: when a serving replica dies, work
        migrated to a sibling must not leave ghost commands on the
        losing queue that a later ``finish(timeout)`` names as stuck
        (tests/test_events.py has the regression).  Returns the
        cancelled events.  Commands already submitted to a worker are
        not cancellable and run (or fail) normally."""
        with self._lock:
            pending, self._pending = self._pending, []
            waiting = [c for c in self._armed
                       if not c.submitted and not c.event.done]
            for c in waiting:
                c.submitted = True     # dep callbacks must not submit
            self._armed.difference_update(waiting)
        victims = pending + waiting
        for c in victims:
            c.event.fail(error if error is not None else DependencyError(
                f"command {c.event.name!r} cancelled before execution"))
        return [c.event for c in victims]

    def finish(self, timeout: Optional[float] = None) -> None:
        """clFinish: flush and wait for completion of *every* issued
        command.  (Waiting only on the last event is wrong for
        out-of-order queues: the last-enqueued command can finish while
        earlier independent commands are still executing.)

        Raises :class:`CommandError` if any command failed, or
        ``RuntimeError`` if ``timeout`` (seconds) expires — e.g. a wait
        list references an event of a queue that was never flushed, or an
        incomplete :class:`~repro.runtime.events.UserEvent`."""
        self.flush()
        with self._lock:
            issued = list(self._issued)
        try:
            if not wait_for_events(issued, timeout):
                # name stuck commands; a fused super-command expands to
                # its constituent kernels (Event.fused_from provenance)
                stuck = []
                for e in issued:
                    if e.done:
                        continue
                    if e.fused_from:
                        parts = ", ".join(o.name for o in e.fused_from)
                        stuck.append(f"{e.name} (fused from: {parts})")
                    else:
                        stuck.append(e.name)
                raise RuntimeError(
                    f"CommandQueue.finish timed out after {timeout}s; "
                    f"incomplete commands: {stuck[:8]}")
        finally:
            with self._lock:
                self._issued = [e for e in self._issued if not e.done]

    def __del__(self):  # pragma: no cover - interpreter shutdown ordering
        try:
            self._pool.shutdown(wait=False)
        except Exception:
            pass


__all__ = ["CommandQueue", "Event", "EventStatus", "UserEvent",
           "CommandError", "DependencyError", "MapError", "MappedRegion",
           "wait_for_events"]
