"""Command queues and events (OpenCL Runtime layer, paper §2/§3).

Commands (kernel launches, buffer reads/writes) are enqueued with optional
event dependencies.  In-order queues preserve enqueue order; out-of-order
queues execute any command whose dependencies are resolved — the analogue of
the paper's observation that commands in an out-of-order queue "can be
assumed to be independent of each other unless explicitly synchronized using
events".

Execution is host-driven: ``flush()`` walks the ready set; a background
thread pool overlaps host-side staging with device execution, which is the
same role the pthread driver's launcher threads play in pocl.

``enqueue_kernel`` is the pocl-faithful enqueue path: the work-group
function is specialized at enqueue time (paper §4.1), but through the
device's compilation cache — so the first enqueue compiles and every later
enqueue of the same kernel/local-size is a hash lookup.  ``self.stats``
counts launches and enqueue-time compiles for the dispatch-overhead story.
"""

from __future__ import annotations

import itertools
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from ..core.api import CompiledKernel
from .platform import Buffer, Device

_event_ids = itertools.count()


class Event:
    """cl_event analogue: a future with status + profiling timestamps."""

    def __init__(self, name: str):
        self.id = next(_event_ids)
        self.name = name
        self.future: Optional[Future] = None
        self._done = threading.Event()

    def complete(self) -> None:
        self._done.set()

    def wait(self) -> None:
        if self.future is not None:
            self.future.result()
        self._done.wait()

    @property
    def done(self) -> bool:
        return self._done.is_set()


class _Command:
    def __init__(self, fn: Callable[[], None], event: Event,
                 deps: Sequence[Event]):
        self.fn = fn
        self.event = event
        self.deps = list(deps)


class CommandQueue:
    def __init__(self, device: Device, out_of_order: bool = False,
                 workers: int = 2):
        self.device = device
        self.out_of_order = out_of_order
        self._pending: List[_Command] = []
        self._pool = ThreadPoolExecutor(max_workers=workers)
        self._lock = threading.Lock()
        self._last_event: Optional[Event] = None
        self._issued: List[Event] = []
        self._launches = 0
        self._compiles0 = device.compile_cache.stats.compiles

    @property
    def stats(self) -> Dict[str, int]:
        """Launch count + pipeline compiles that hit this queue's *device*
        cache since queue creation.  The compile counter is device-wide:
        other queues (or direct ``build_kernel`` calls) on the same device
        contribute, and an autotuned device compiles one candidate per
        target on first launch.  Compiles are single-flight, so for a
        single queue on a static-target device the steady state is exactly
        1 per distinct kernel/local-size."""
        with self._lock:
            launches = self._launches
        return {"launches": launches,
                "enqueue_compiles":
                    self.device.compile_cache.stats.compiles
                    - self._compiles0}

    # -- enqueue APIs -------------------------------------------------------------
    def _enqueue(self, name: str, fn: Callable[[], None],
                 wait_for: Optional[Sequence[Event]]) -> Event:
        ev = Event(name)
        deps = list(wait_for or [])
        if not self.out_of_order and self._last_event is not None:
            deps.append(self._last_event)
        with self._lock:
            self._pending.append(_Command(fn, ev, deps))
            self._last_event = ev
            self._issued.append(ev)
        return ev

    def enqueue_write_buffer(self, buf: Buffer, host: np.ndarray,
                             wait_for=None) -> Event:
        def run():
            buf.data = np.array(host, dtype=buf.dtype, copy=True)
        return self._enqueue("write", run, wait_for)

    def enqueue_read_buffer(self, buf: Buffer, out: np.ndarray,
                            wait_for=None) -> Event:
        def run():
            out[...] = buf.data
        return self._enqueue("read", run, wait_for)

    def enqueue_ndrange_kernel(self, kernel: CompiledKernel,
                               global_size: Sequence[int],
                               buffers: Dict[str, Buffer],
                               scalars: Optional[Dict[str, object]] = None,
                               wait_for=None) -> Event:
        def run():
            self._launch(kernel, buffers, global_size, scalars)
        return self._enqueue(f"ndrange:{kernel.name}", run, wait_for)

    def enqueue_kernel(self, build, local_size: Sequence[int],
                       global_size: Sequence[int],
                       buffers: Dict[str, Buffer],
                       scalars: Optional[Dict[str, object]] = None,
                       wait_for=None, **opts) -> Event:
        """Enqueue-time specialization (paper §4.1): compile ``build`` for
        ``local_size`` on this queue's device and launch it.  Compilation
        goes through the device cache, so a steady-state enqueue does zero
        region-formation or lowering work."""
        def run():
            kernel = self.device.build_kernel(build, local_size, **opts)
            self._launch(kernel, buffers, global_size, scalars)
        return self._enqueue("ndrange:<enqueue-compiled>", run, wait_for)

    def _launch(self, kernel, buffers: Dict[str, Buffer], global_size,
                scalars) -> None:
        """Run a compiled kernel over device buffers and write back."""
        with self._lock:
            self._launches += 1
        arrs = {k: b.data for k, b in buffers.items()}
        out = kernel(arrs, global_size, scalars)
        for k, b in buffers.items():
            b.data = out[k]

    def enqueue_barrier(self) -> Event:
        """Queue barrier: waits for everything enqueued so far."""
        with self._lock:
            deps = [c.event for c in self._pending]
            if self._last_event is not None:
                deps.append(self._last_event)
        return self._enqueue("queue-barrier", lambda: None, deps)

    # -- execution -----------------------------------------------------------------
    def flush(self) -> None:
        """Submit every command whose dependencies are resolved; loop until
        the queue drains (dependencies between pending commands resolve as
        their predecessors complete)."""
        with self._lock:
            # completed events need no further tracking; pruning here keeps
            # _issued bounded on long-lived queues driven by flush() alone
            self._issued = [e for e in self._issued if not e.done]
        while True:
            with self._lock:
                if not self._pending:
                    return
                ready = [c for c in self._pending
                         if all(d.done for d in c.deps)]
                for c in ready:
                    self._pending.remove(c)
            if not ready:
                # wait for any in-flight command, then retry
                with self._lock:
                    blockers = [d for c in self._pending for d in c.deps]
                for d in blockers:
                    if d.future is not None:
                        d.wait()
                        break
                else:
                    raise RuntimeError("command queue deadlock")
                continue
            for c in ready:
                def run(c=c):
                    try:
                        c.fn()
                    finally:
                        c.event.complete()
                c.event.future = self._pool.submit(run)
            for c in ready:
                if not self.out_of_order:
                    c.event.wait()
        # unreachable

    def finish(self) -> None:
        """clFinish: flush and wait for completion of *every* issued
        command.  (Waiting only on the last event is wrong for
        out-of-order queues: the last-enqueued command can finish while
        earlier independent commands are still executing.)"""
        self.flush()
        with self._lock:
            issued = list(self._issued)
        for ev in issued:
            ev.wait()
