from .pipeline import synth_batch, data_iterator
