"""Deterministic synthetic LM data pipeline with background prefetch.

Production shape: each step's batch is generated deterministically from
(seed, step) so every data-parallel worker can synthesize ITS OWN shard
without any shared storage or shuffling service — restart-safe (resume at
step k regenerates the same stream) and elastic (resharding just changes
which slice each worker materializes).  A small double-buffer thread
prefetches the next batch while the current step runs (compute/IO overlap).
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator

import numpy as np

from repro.models import ModelConfig


def synth_batch(cfg: ModelConfig, batch: int, seq: int, step: int,
                seed: int = 0) -> Dict[str, np.ndarray]:
    """Markov-ish synthetic tokens: structured enough that a model can
    reduce loss, deterministic in (seed, step)."""
    rng = np.random.default_rng(np.uint64(seed) * 1_000_003 + np.uint64(step))
    # low-entropy stream: a small effective vocabulary with Zipf-ish mass
    # (so smoke-scale models show clear loss descent within tens of steps)
    # + copy structure in the second half (exercises attention/induction).
    v_eff = min(64, cfg.vocab)
    p = 1.0 / np.arange(1, v_eff + 1)
    p /= p.sum()
    base = rng.choice(v_eff, size=(batch, seq + 1), p=p)
    half = (seq + 1) // 2
    base[:, half:half * 2] = base[:, :half]
    toks = base.astype(np.int32)
    out = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
    if cfg.family == "vlm":
        out["img_embeds"] = rng.standard_normal(
            (batch, cfg.n_img_tokens, cfg.d_model)).astype(np.float32)
    if cfg.family == "encdec":
        out["frames"] = rng.standard_normal(
            (batch, cfg.enc_seq, cfg.d_model)).astype(np.float32)
    return out


def data_iterator(cfg: ModelConfig, batch: int, seq: int, *,
                  start_step: int = 0, seed: int = 0,
                  prefetch: int = 2) -> Iterator[Dict[str, np.ndarray]]:
    """Background-threaded prefetching iterator."""
    q: "queue.Queue" = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def producer():
        step = start_step
        while not stop.is_set():
            b = synth_batch(cfg, batch, seq, step, seed)
            while not stop.is_set():
                try:
                    q.put(b, timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()
