"""The pocl host-runtime path (paper §2/§3) through the first-class
object model (docs/host_api.md): context creation, program build, typed
buffer allocation, kernel argument binding, an out-of-order event queue,
event profiling, and one NDRange co-executed across two devices with the
*same* Kernel object as the single-device launch.

  PYTHONPATH=src python examples/opencl_runtime.py
"""

import numpy as np

from repro.core import KernelBuilder
from repro.runtime import Context


def build_scale():
    b = KernelBuilder("scale")
    x = b.arg_buffer("x", "float32")
    s = b.arg_scalar("s", "float32")
    g = b.global_id(0)
    x[g] = x[g] * s
    return b.finish()


def build_offset():
    b = KernelBuilder("offset")
    x = b.arg_buffer("x", "float32")
    o = b.arg_scalar("o", "float32")
    g = b.global_id(0)
    x[g] = x[g] + o
    return b.finish()


def main():
    ctx = Context()                                    # clCreateContext
    print("context devices:")
    for d in ctx.devices:
        print(f"  {d.info.name}: driver={d.info.driver} "
              f"global_mem={d.query('global_mem_size') >> 20}MiB "
              f"max_wg={d.query('max_work_group_size')}")

    # one program holding both kernels (clBuildProgram builds them
    # together; specialization per local size stays lazy, paper §4.1)
    prog = ctx.create_program(build_scale, build_offset).build()
    scale = prog.create_kernel("scale")
    offset = prog.create_kernel("offset")

    n = 256
    host = np.arange(n, dtype=np.float32)
    out = np.zeros(n, np.float32)
    buf = ctx.create_buffer(n, "float32")              # clCreateBuffer

    # clSetKernelArg: bind the device buffer + scalars once; the same
    # kernel objects are enqueued below and (for scale) co-executed
    scale.set_args(x=buf, s=2.0)
    offset.set_args(x=buf, o=1.0)

    # event-ordered pipeline on an out-of-order queue:
    # write -> scale -> offset -> read
    q = ctx.create_queue(out_of_order=True)
    e_w = q.enqueue_write_buffer(buf, host)
    e_s = q.enqueue_nd_range(scale, (n,), (64,), wait_for=[e_w])
    e_o = q.enqueue_nd_range(offset, (n,), (64,), wait_for=[e_s])
    e_r = q.enqueue_read_buffer(buf, out, wait_for=[e_o])
    q.finish()

    np.testing.assert_allclose(out, host * 2.0 + 1.0)
    print(f"pipeline OK: buffer at chunk offset {buf.chunk.start}, "
          f"result[:4]={out[:4].tolist()}")

    # event profiling: the clGetEventProfilingInfo counters
    print("event profile (us relative to first enqueue):")
    t0 = e_w.queued_ns
    for ev in (e_w, e_s, e_o, e_r):
        p = ev.profile
        print(f"  {ev.name:14s} queued={(p['queued_ns'] - t0) / 1e3:8.1f} "
              f"submit={(p['submit_ns'] - t0) / 1e3:8.1f} "
              f"start={(p['start_ns'] - t0) / 1e3:8.1f} "
              f"end={(p['end_ns'] - t0) / 1e3:8.1f}")
    buf.release()

    # multi-device co-execution: the SAME Kernel object (cloned so the
    # host-array binding never races the queue path), split across two
    # devices — bitwise identical to the single-device result
    k_host = scale.clone().set_arg("x", host.copy())
    single = ctx.launch(k_host, (n,), (64,))
    co = ctx.create_co_executor(ctx.platform.co_devices(2))
    merged = co.launch(k_host.clone(), (n,), (64,), mode="static")
    assert merged["x"].tobytes() == single["x"].tobytes()
    st = co.last_stats
    print(f"co-execution OK: groups per device {st.groups_per_device}, "
          f"{st.migrations} buffer migrations")
    co.finish()


if __name__ == "__main__":
    main()
