"""The pocl host-runtime path (paper §2/§3): platform query, buffer
allocation through Bufalloc, command queues with event dependencies, an
out-of-order queue exploiting command-level parallelism, event profiling,
and one NDRange co-executed across two devices (docs/runtime.md).

  PYTHONPATH=src python examples/opencl_runtime.py
"""

import numpy as np

from repro.core import KernelBuilder
from repro.runtime import CoExecutor
from repro.runtime.platform import Platform, create_buffer
from repro.runtime.queue import CommandQueue


def build_scale():
    b = KernelBuilder("scale")
    x = b.arg_buffer("x", "float32")
    s = b.arg_scalar("s", "float32")
    g = b.global_id(0)
    x[g] = x[g] * s
    return b.finish()


def build_offset():
    b = KernelBuilder("offset")
    x = b.arg_buffer("x", "float32")
    o = b.arg_scalar("o", "float32")
    g = b.global_id(0)
    x[g] = x[g] + o
    return b.finish()


def main():
    plat = Platform()
    print("platform devices:")
    for d in plat.get_devices():
        print(f"  {d.info.name}: driver={d.info.driver} "
              f"global_mem={d.query('global_mem_size') >> 20}MiB "
              f"max_wg={d.query('max_work_group_size')}")

    dev = plat.get_devices()[0]
    scale = dev.build_kernel(build_scale, (64,))
    offset = dev.build_kernel(build_offset, (64,))

    n = 256
    host = np.arange(n, dtype=np.float32)
    out = np.zeros(n, np.float32)
    buf = create_buffer(dev, n, "float32")

    # event-ordered pipeline on an out-of-order queue:
    # write -> scale -> offset -> read
    q = CommandQueue(dev, out_of_order=True)
    e_w = q.enqueue_write_buffer(buf, host)
    e_s = q.enqueue_ndrange_kernel(scale, (n,), {"x": buf}, {"s": 2.0},
                                   wait_for=[e_w])
    e_o = q.enqueue_ndrange_kernel(offset, (n,), {"x": buf}, {"o": 1.0},
                                   wait_for=[e_s])
    e_r = q.enqueue_read_buffer(buf, out, wait_for=[e_o])
    q.finish()

    np.testing.assert_allclose(out, host * 2.0 + 1.0)
    print(f"pipeline OK: buffer at chunk offset {buf.chunk.start}, "
          f"result[:4]={out[:4].tolist()}")

    # event profiling: the clGetEventProfilingInfo counters
    print("event profile (us relative to first enqueue):")
    t0 = e_w.queued_ns
    for ev in (e_w, e_s, e_o, e_r):
        p = ev.profile
        print(f"  {ev.name:14s} queued={(p['queued_ns'] - t0) / 1e3:8.1f} "
              f"submit={(p['submit_ns'] - t0) / 1e3:8.1f} "
              f"start={(p['start_ns'] - t0) / 1e3:8.1f} "
              f"end={(p['end_ns'] - t0) / 1e3:8.1f}")
    buf.release()

    # multi-device co-execution: one NDRange split across two devices,
    # bitwise identical to the single-device result
    single = scale({"x": host.copy()}, (n,), {"s": 2.0})
    co = CoExecutor(plat.co_devices(2))
    merged = co.run(build_scale, (64,), (n,), {"x": host.copy()},
                    {"s": 2.0}, mode="static")
    assert merged["x"].tobytes() == np.asarray(single["x"]).tobytes()
    st = co.last_stats
    print(f"co-execution OK: groups per device {st.groups_per_device}, "
          f"{st.migrations} buffer migrations")
    co.finish()


if __name__ == "__main__":
    main()
