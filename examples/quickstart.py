"""Quickstart: the pocl kernel compiler in 60 seconds.

Authors the paper's Fig. 1 vector dot-product kernel in the SPMD DSL
(the OpenCL C analogue), builds it through the first-class host object
model — Context -> Program -> Kernel (docs/host_api.md) — for two
parallel mappings, and validates against the fiber-semantics oracle.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import KernelBuilder
# sanctioned oracle use: this example demonstrates validating against the
# fiber reference executor (see ruff.toml banned-api)
from repro.core import run_ndrange  # noqa: TID251
from repro.runtime import Context


def build_dot_product():
    """__kernel void dot(__global float4 *a, b, c)  (paper Fig. 1)."""
    b = KernelBuilder("dot_product")
    a_ = b.arg_buffer("a", "float32")
    b_ = b.arg_buffer("b", "float32")
    c_ = b.arg_buffer("c", "float32")
    gid = b.global_id(0)
    # float4 dot product: each work-item reduces 4 adjacent lanes
    acc = b.var(0.0, name="acc")
    i = b.var(b.const(0), name="i")
    with b.while_loop() as loop:
        loop.cond(i.get() < 4)
        acc.set(acc.get() + a_[gid * 4 + i.get()] * b_[gid * 4 + i.get()])
        i.set(i.get() + 1)
    c_[gid] = acc.get()
    return b.finish()


def main():
    n = 256
    rng = np.random.default_rng(0)
    a = rng.standard_normal(n * 4).astype(np.float32)
    b = rng.standard_normal(n * 4).astype(np.float32)

    # 1. semantics oracle: fiber execution (Clover/Twin-Peaks style)
    ref = run_ndrange(build_dot_product(), (n,), (64,),
                      {"a": a.copy(), "b": b.copy(),
                       "c": np.zeros(n, np.float32)})

    # 2. the host object model (docs/host_api.md): one Program, one
    #    Kernel with clSetKernelArg-style bound arguments; the pocl
    #    pipeline specializes lazily per (device, local_size, target)
    ctx = Context()
    prog = ctx.create_program(build_dot_product).build()
    print(f"program kernels={prog.kernel_names()}")
    kernel = prog.create_kernel("dot_product")
    kernel.set_args(a=a, b=b, c=np.zeros(n, np.float32))

    for target in ("loop", "vector"):
        out = ctx.launch(kernel, (n,), (64,), target=target)
        np.testing.assert_allclose(out["c"], ref["c"], rtol=1e-5, atol=2e-6)
        binary = kernel.bind(ctx.devices[0], (64,), target=target)
        print(f"target={target:7s} regions={binary.num_regions} "
              f"context={binary.context_stats} OK")

    expect = (a.reshape(-1, 4) * b.reshape(-1, 4)).sum(1)
    np.testing.assert_allclose(ref["c"], expect, rtol=1e-5, atol=2e-6)
    print("dot product matches numpy; all targets agree with the oracle")


if __name__ == "__main__":
    main()
