"""Batched serving example: prefill + synchronized batched decode with a
KV cache, request grouping, greedy sampling.

The engine's runtime resources come from the first-class host Context
(docs/host_api.md): the driver builds a ``Context``, the engine creates
its dispatch queue through it, and per-group KV blocks are accounted on
the context's per-device BufferPool — the same object model that backs
kernel launches and multi-device co-execution.

  PYTHONPATH=src python examples/serve_lm.py
"""

from repro.launch import serve as serve_cli


def main():
    serve_cli.main(["--arch", "smollm-135m", "--smoke", "--requests", "6",
                    "--max-new", "12", "--batch-slots", "2",
                    "--max-seq", "64"])


if __name__ == "__main__":
    main()
