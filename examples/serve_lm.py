"""Continuous-batching serving example: requests are submitted into the
engine's admission queue on a staggered schedule and the scheduler is
pumped with ``step()`` — per-step slot refill, paged KV from the context
BufferPool, decode overlapping refill prefills on the event DAG
(docs/serving.md).

The engine's runtime resources come from the first-class host Context
(docs/host_api.md): the driver builds a ``Context``, the engine creates
its dispatch queue through it, and per-request KV pages are accounted on
the context's per-device BufferPool — the same object model that backs
kernel launches and multi-device co-execution.

  PYTHONPATH=src python examples/serve_lm.py
"""

from repro.launch import serve as serve_cli


def main():
    serve_cli.main(["--arch", "smollm-135m", "--smoke", "--requests", "6",
                    "--max-new", "12", "--batch-slots", "2",
                    "--max-seq", "64", "--arrival-every", "2"])


if __name__ == "__main__":
    main()
