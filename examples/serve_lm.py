"""Batched serving example: prefill + synchronized batched decode with a
KV cache, request grouping, greedy sampling.

  PYTHONPATH=src python examples/serve_lm.py
"""

from repro.launch import serve as serve_cli


def main():
    serve_cli.main(["--arch", "smollm-135m", "--smoke", "--requests", "6",
                    "--max-new", "12", "--batch-slots", "2",
                    "--max-seq", "64"])


if __name__ == "__main__":
    main()
