"""End-to-end training driver example.

Default (CPU demo, ~1 minute): trains the reduced smollm config for 150
steps on the synthetic pipeline, with checkpointing + resume.

The REAL run this driver exists for (the ~100M-param example from the
deliverables) is the full SmolLM-135M config; on a TPU slice:

  PYTHONPATH=src python examples/train_lm.py --full --steps 300 \
      --batch 32 --seq 1024 --ckpt-dir /tmp/smollm_run

(the same flags work on CPU — expect ~15 s/step at batch 2, seq 64).

Training drives jax directly (no repro runtime objects on the hot
path); the serving-side counterpart (examples/serve_lm.py) shows the
first-class Context / Program / Kernel host API (docs/host_api.md).

  PYTHONPATH=src python examples/train_lm.py
"""

import argparse

from repro.launch import train as train_cli


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full 135M config instead of the smoke config")
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_quickstart_ckpt")
    args, extra = ap.parse_known_args()

    argv = ["--arch", "smollm-135m", "--steps", str(args.steps),
            "--batch", str(args.batch), "--seq", str(args.seq),
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
            "--lr", "3e-3", "--log-every", "25"] + extra
    if not args.full:
        argv.append("--smoke")
    train_cli.main(argv)


if __name__ == "__main__":
    main()
